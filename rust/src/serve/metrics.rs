//! Serving telemetry: TTFT, per-token latency percentiles, tokens/sec,
//! queue depth and in-flight occupancy — the numbers a serving fleet is
//! tuned by, exportable as JSON (for `BENCH_serving.json` trajectories)
//! and as a markdown table through [`crate::report`].

use crate::report::Table;
use crate::util::json::{self, Json};
use crate::util::timer::Samples;

/// Rolling counters for one scheduler run. All durations are stored in
/// microseconds ([`Samples`] convention); accessors convert to ms.
#[derive(Clone, Debug, Default)]
pub struct ServingMetrics {
    /// Engine step latency (us), one sample per decode step.
    pub step_us: Samples,
    /// Batched prefill call latency (us), one sample per prefill call —
    /// kept separate from `step_us`/`token_us` so prompt ingestion cost
    /// (which sets TTFT) never pollutes per-token decode latency.
    pub prefill_us: Samples,
    /// User-perceived per-token latency (us): the duration of the step that
    /// produced the token, one sample per *generated* token.
    pub token_us: Samples,
    /// Time to first generated token (us), measured from request *enqueue*
    /// (not admission, not step start), one sample per request.
    pub ttft_us: Samples,
    /// Total request latency (us), submit -> completion.
    pub request_us: Samples,
    /// Admission-queue depth, sampled once per step.
    pub queue_depth: Samples,
    /// Occupied slots, sampled once per step.
    pub in_flight: Samples,
    pub tokens_generated: usize,
    /// Prompt tokens consumed through batched prefill calls.
    pub tokens_prefilled: usize,
    pub requests_completed: usize,
    /// Paged serving: requests evicted back to the queue (pool exhaustion);
    /// each restarts from scratch later, so high counts mean the admission
    /// watermark is too optimistic for the workload.
    pub requests_evicted: usize,
    /// Prefix cache: prompt tokens served from already-resident shared
    /// pages at admission (never recomputed, never re-fed).
    pub tokens_reused: usize,
    /// Prompt tokens across all admissions (re-admissions after eviction
    /// included) — the denominator of [`ServingMetrics::prefix_hit_rate`].
    pub prompt_tokens_admitted: usize,
    /// Admissions that mapped at least one cached prefix page.
    pub prefix_hits: usize,
    /// Decode-stall histogram: one sample per token produced by a slot that
    /// was already *running* (prompt fully fed) at the start of the
    /// iteration — the number of earlier engine-call iterations the slot
    /// sat through without producing anything since its previous token
    /// (0 = a token every iteration). Budget-off chunked prefill makes this
    /// spike to `ceil(len/chunk)` while a long prompt drains; the step
    /// composer (`--step-budget`) exists to pin it at 0.
    pub decode_stall_steps: Samples,
    /// Inter-token latency (us): engine-busy time between a running slot's
    /// consecutive tokens, every stalled iteration's call time included —
    /// the user-perceived hiccup `decode_stall_steps` counts in steps.
    pub inter_token_us: Samples,
    /// Per-iteration share of fed tokens that were prompt (prefill) tokens,
    /// one sample per iteration that fed anything. Under a step budget this
    /// gauges how the composer actually split each step.
    pub prefill_share: Samples,
    /// Composed iterations that paired a decode call with a prefill call
    /// (only the step composer produces these).
    pub mixed_steps: usize,
    /// Queue wait (us): enqueue -> the first time the request's tokens
    /// entered an engine call, one sample per completed request that
    /// generated a token (recorded at retirement, paired 1:1 with
    /// `ttft_us`). Split out of TTFT so prefill spread (chunk splitting
    /// across many budgeted steps) cannot masquerade as queue wait, or
    /// vice versa.
    pub queue_us: Samples,
    /// Prefill spread (us): first scheduled -> first generated token, the
    /// other half of TTFT (`ttft == queue + spread`, same clock, stamped at
    /// the same instant).
    pub prefill_spread_us: Samples,
    /// Step-wide (transient) engine faults absorbed by the error kernel.
    pub step_faults: usize,
    /// Per-slot engine faults absorbed by the error kernel.
    pub slot_faults: usize,
    /// Retries scheduled with a step-counted backoff (per-slot cooldowns
    /// and step-wide pauses both count).
    pub retries_scheduled: usize,
    /// Slots whose next engine call after a fault succeeded (the retry
    /// worked; the request kept its KV state).
    pub slots_recovered: usize,
    /// Requests retired with [`FinishReason::Quarantined`]: individually
    /// charged `retry_budget` faults (poison-request isolation).
    ///
    /// [`FinishReason::Quarantined`]: crate::serve::trace::FinishReason::Quarantined
    pub requests_quarantined: usize,
    /// Requests evicted to the queue front by step-wide retry exhaustion
    /// (warm restart through the donated-page path) — counted apart from
    /// `requests_evicted`, which is pool pressure, not engine failure.
    pub requests_fault_evicted: usize,
    /// Requests shed in the admission queue because their deadline expired
    /// before they ever reached a slot.
    pub deadline_shed_queued: usize,
    /// Requests shed mid-flight (slot freed, partial output returned)
    /// because their deadline expired.
    pub deadline_shed_inflight: usize,
    /// Speculative decoding: draft tokens that entered a verify call
    /// (counted at plan time, so a faulted verify still counts its
    /// proposal — mirroring the trace's `DraftProposed` events exactly).
    pub draft_tokens_proposed: usize,
    /// Speculative decoding: draft tokens the target engine agreed with
    /// (the accepted prefix; bonus correction tokens are ordinary
    /// generated tokens and are not counted here).
    pub draft_tokens_accepted: usize,
    /// Verify engine calls issued by the speculative decode path (each
    /// replaces what would have been one plain decode step).
    pub verify_calls: usize,
    /// Engine-drafter time (us), one sample per drafted window: the
    /// re-sync feed plus the K-step draft loop a [`SpecDraft::Engine`]
    /// rung runs before each verify call. The n-gram drafter costs no
    /// engine work and records nothing here. Counted into [`busy_secs`]
    /// — leaving it out overstated `tokens_per_sec` whenever an engine
    /// drafter was in the loop.
    ///
    /// [`SpecDraft::Engine`]: super::scheduler::SpecDraft::Engine
    /// [`busy_secs`]: Self::busy_secs
    pub draft_us: Samples,
}

impl ServingMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one engine step: its latency, how many tokens it yielded,
    /// and the scheduler state around it.
    pub fn record_step(&mut self, step_us: f64, new_tokens: usize, in_flight: usize, queue: usize) {
        self.step_us.push(step_us);
        for _ in 0..new_tokens {
            self.token_us.push(step_us);
        }
        self.tokens_generated += new_tokens;
        self.in_flight.push(in_flight as f64);
        self.queue_depth.push(queue as f64);
    }

    /// Record one batched prefill call: its latency, how many prompt tokens
    /// it consumed, how many first tokens it yielded (a chunk that finishes
    /// a prompt samples the request's first token), and the scheduler state
    /// around it.
    pub fn record_prefill(
        &mut self,
        prefill_us: f64,
        prompt_tokens: usize,
        new_tokens: usize,
        in_flight: usize,
        queue: usize,
    ) {
        self.prefill_us.push(prefill_us);
        self.tokens_prefilled += prompt_tokens;
        for _ in 0..new_tokens {
            self.token_us.push(prefill_us);
        }
        self.tokens_generated += new_tokens;
        self.in_flight.push(in_flight as f64);
        self.queue_depth.push(queue as f64);
    }

    /// Record a pool-exhaustion eviction (paged serving only).
    pub fn record_eviction(&mut self) {
        self.requests_evicted += 1;
    }

    /// Record one admission: `reused` of the request's `prompt_len` prompt
    /// tokens were mapped from already-resident shared prefix pages
    /// (always 0 with the prefix cache off).
    pub fn record_admission(&mut self, reused: usize, prompt_len: usize) {
        self.tokens_reused += reused;
        self.prompt_tokens_admitted += prompt_len;
        if reused > 0 {
            self.prefix_hits += 1;
        }
    }

    /// Fraction of admitted prompt tokens served from the prefix cache
    /// instead of being recomputed; 0 when nothing was admitted.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prompt_tokens_admitted == 0 {
            return 0.0;
        }
        self.tokens_reused as f64 / self.prompt_tokens_admitted as f64
    }

    /// Record one token produced by a *running* slot: how many engine-call
    /// iterations it stalled since its previous token (0 = none) and the
    /// engine-busy microseconds that wait amounted to.
    pub fn record_decode_token_wait(&mut self, stall_steps: usize, wait_us: f64) {
        self.decode_stall_steps.push(stall_steps as f64);
        self.inter_token_us.push(wait_us);
    }

    /// Record one iteration's fed-token mix: `prompt_tokens` prompt tokens
    /// against `decode_tokens` generated-feedback tokens (no sample when
    /// the iteration fed nothing).
    pub fn record_token_mix(&mut self, prompt_tokens: usize, decode_tokens: usize) {
        let total = prompt_tokens + decode_tokens;
        if total > 0 {
            self.prefill_share.push(prompt_tokens as f64 / total as f64);
        }
    }

    /// Record one composed iteration that ran both a decode call and a
    /// prefill call.
    pub fn record_mixed_step(&mut self) {
        self.mixed_steps += 1;
    }

    /// Record a request's TTFT split: `queue_us` (enqueue -> first
    /// scheduled) and `spread_us` (first scheduled -> first token), the
    /// two halves of TTFT. Called once per completed request that
    /// generated a token, so the pair stays 1:1 with the `ttft_us`
    /// samples even across eviction restarts.
    pub fn record_first_token(&mut self, queue_us: f64, spread_us: f64) {
        self.queue_us.push(queue_us);
        self.prefill_spread_us.push(spread_us);
    }

    /// Worst stall any running slot experienced (in engine-call
    /// iterations); 0 when no slot ever waited — the composer's acceptance
    /// observable.
    pub fn max_decode_stall_steps(&self) -> usize {
        self.decode_stall_steps.percentile_us(100.0) as usize
    }

    pub fn inter_token_ms_p99(&self) -> f64 {
        self.inter_token_us.percentile_us(99.0) / 1e3
    }

    pub fn mean_prefill_share(&self) -> f64 {
        self.prefill_share.mean_us()
    }

    pub fn queue_ms_p50(&self) -> f64 {
        self.queue_us.percentile_us(50.0) / 1e3
    }

    pub fn prefill_spread_ms_p50(&self) -> f64 {
        self.prefill_spread_us.percentile_us(50.0) / 1e3
    }

    /// Record a step-wide (transient) engine fault absorbed by the kernel.
    pub fn record_step_fault(&mut self) {
        self.step_faults += 1;
    }

    /// Record a per-slot engine fault absorbed by the kernel.
    pub fn record_slot_fault(&mut self) {
        self.slot_faults += 1;
    }

    /// Record a retry scheduled with a step-counted backoff.
    pub fn record_retry(&mut self) {
        self.retries_scheduled += 1;
    }

    /// Record a slot whose first engine call after a fault succeeded.
    pub fn record_recovery(&mut self) {
        self.slots_recovered += 1;
    }

    /// Record a request quarantined after exhausting its retry budget.
    pub fn record_quarantine(&mut self) {
        self.requests_quarantined += 1;
    }

    /// Record a warm-restart eviction caused by step-wide retry exhaustion.
    pub fn record_fault_eviction(&mut self) {
        self.requests_fault_evicted += 1;
    }

    /// Record a queued request shed at admission for an expired deadline.
    pub fn record_deadline_shed_queued(&mut self) {
        self.deadline_shed_queued += 1;
    }

    /// Record an in-flight request shed for an expired deadline.
    pub fn record_deadline_shed_inflight(&mut self) {
        self.deadline_shed_inflight += 1;
    }

    /// Record a draft window entering a verify call (`tokens` proposed).
    pub fn record_draft_proposed(&mut self, tokens: usize) {
        self.draft_tokens_proposed += tokens;
    }

    /// Record how many of a window's drafts the target engine accepted.
    pub fn record_draft_accepted(&mut self, accepted: usize) {
        self.draft_tokens_accepted += accepted;
    }

    /// Record one verify engine call.
    pub fn record_verify_call(&mut self) {
        self.verify_calls += 1;
    }

    /// Record the engine-drafter work behind one drafted window (re-sync
    /// feed + draft loop), in microseconds.
    pub fn record_draft_call(&mut self, draft_us: f64) {
        self.draft_us.push(draft_us);
    }

    /// Fraction of proposed draft tokens the target engine accepted;
    /// 0 when nothing was ever proposed. Proposals stranded by a verify
    /// fault count against the rate (they cost a draft, bought nothing).
    pub fn accept_rate(&self) -> f64 {
        if self.draft_tokens_proposed == 0 {
            return 0.0;
        }
        self.draft_tokens_accepted as f64 / self.draft_tokens_proposed as f64
    }

    /// Requests that failed (quarantine or deadline shed) rather than
    /// completing — the goodput denominator's loss term.
    pub fn requests_failed(&self) -> usize {
        self.requests_quarantined + self.deadline_shed_queued + self.deadline_shed_inflight
    }

    /// Record a completed request (latencies in microseconds).
    pub fn record_completion(&mut self, request_us: f64, ttft_us: Option<f64>) {
        self.requests_completed += 1;
        self.request_us.push(request_us);
        if let Some(t) = ttft_us {
            self.ttft_us.push(t);
        }
    }

    /// Engine busy time: the sum of decode-step, prefill-call, and
    /// engine-drafter latencies, in seconds. In the single-threaded
    /// scheduler this is the serving wall clock. Speculative *verify*
    /// calls need no term of their own: each one is recorded through
    /// `record_step` (it replaces a plain decode step), so verify latency
    /// is already in this denominator exactly once — `verify_calls` is a
    /// pure counter, never a second timing source, so nothing is
    /// double-counted.
    pub fn busy_secs(&self) -> f64 {
        (self.step_us.mean_us() * self.step_us.len() as f64
            + self.prefill_us.mean_us() * self.prefill_us.len() as f64
            + self.draft_us.mean_us() * self.draft_us.len() as f64)
            / 1e6
    }

    /// Aggregate generation throughput over the whole run.
    pub fn tokens_per_sec(&self) -> f64 {
        let s = self.busy_secs();
        if s <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / s
    }

    pub fn token_ms_p50(&self) -> f64 {
        self.token_us.percentile_us(50.0) / 1e3
    }

    pub fn token_ms_p95(&self) -> f64 {
        self.token_us.percentile_us(95.0) / 1e3
    }

    pub fn token_ms_p99(&self) -> f64 {
        self.token_us.percentile_us(99.0) / 1e3
    }

    pub fn ttft_ms_p50(&self) -> f64 {
        self.ttft_us.percentile_us(50.0) / 1e3
    }

    pub fn ttft_ms_p95(&self) -> f64 {
        self.ttft_us.percentile_us(95.0) / 1e3
    }

    pub fn prefill_ms_p50(&self) -> f64 {
        self.prefill_us.percentile_us(50.0) / 1e3
    }

    pub fn mean_queue_depth(&self) -> f64 {
        self.queue_depth.mean_us()
    }

    pub fn mean_in_flight(&self) -> f64 {
        self.in_flight.mean_us()
    }

    /// JSON export (the `BENCH_serving.json` row shape). The multi-read
    /// sample sets (`token_us` grows one sample per generated token) are
    /// read through [`Samples::percentiles_us`], one sort per set instead
    /// of one per percentile.
    pub fn to_json(&self) -> Json {
        let token = self.token_us.percentiles_us(&[50.0, 95.0, 99.0]);
        let ttft = self.ttft_us.percentiles_us(&[50.0, 95.0]);
        json::obj(vec![
            ("requests", json::num(self.requests_completed as f64)),
            ("tokens", json::num(self.tokens_generated as f64)),
            ("steps", json::num(self.step_us.len() as f64)),
            ("tokens_per_sec", json::num(self.tokens_per_sec())),
            ("token_ms_p50", json::num(token[0] / 1e3)),
            ("token_ms_p95", json::num(token[1] / 1e3)),
            ("token_ms_p99", json::num(token[2] / 1e3)),
            ("ttft_ms_p50", json::num(ttft[0] / 1e3)),
            ("ttft_ms_p95", json::num(ttft[1] / 1e3)),
            ("prefill_calls", json::num(self.prefill_us.len() as f64)),
            ("prefill_ms_p50", json::num(self.prefill_ms_p50())),
            ("tokens_prefilled", json::num(self.tokens_prefilled as f64)),
            ("request_ms_mean", json::num(self.request_us.mean_us() / 1e3)),
            ("mean_queue_depth", json::num(self.mean_queue_depth())),
            ("mean_in_flight", json::num(self.mean_in_flight())),
            ("requests_evicted", json::num(self.requests_evicted as f64)),
            ("tokens_reused", json::num(self.tokens_reused as f64)),
            ("prefix_hits", json::num(self.prefix_hits as f64)),
            ("prefix_hit_rate", json::num(self.prefix_hit_rate())),
            ("max_decode_stall_steps", json::num(self.max_decode_stall_steps() as f64)),
            ("inter_token_ms_p99", json::num(self.inter_token_ms_p99())),
            ("mean_prefill_share", json::num(self.mean_prefill_share())),
            ("mixed_steps", json::num(self.mixed_steps as f64)),
            ("queue_ms_p50", json::num(self.queue_ms_p50())),
            ("prefill_spread_ms_p50", json::num(self.prefill_spread_ms_p50())),
            ("step_faults", json::num(self.step_faults as f64)),
            ("slot_faults", json::num(self.slot_faults as f64)),
            ("retries_scheduled", json::num(self.retries_scheduled as f64)),
            ("slots_recovered", json::num(self.slots_recovered as f64)),
            ("requests_quarantined", json::num(self.requests_quarantined as f64)),
            ("requests_fault_evicted", json::num(self.requests_fault_evicted as f64)),
            ("deadline_shed_queued", json::num(self.deadline_shed_queued as f64)),
            ("deadline_shed_inflight", json::num(self.deadline_shed_inflight as f64)),
            ("draft_tokens_proposed", json::num(self.draft_tokens_proposed as f64)),
            ("draft_tokens_accepted", json::num(self.draft_tokens_accepted as f64)),
            ("accept_rate", json::num(self.accept_rate())),
            ("verify_calls", json::num(self.verify_calls as f64)),
            ("draft_calls", json::num(self.draft_us.len() as f64)),
            ("draft_ms_mean", json::num(self.draft_us.mean_us() / 1e3)),
            (
                "histograms",
                json::obj(vec![
                    ("inter_token_ms", latency_histogram(&self.inter_token_us)),
                    ("ttft_ms", latency_histogram(&self.ttft_us)),
                ]),
            ),
        ])
    }

    /// One-row markdown table for CLI output.
    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &[
                "req",
                "tokens",
                "tok/s",
                "p50 ms/tok",
                "p95",
                "p99",
                "TTFT p50 ms",
                "queue avg",
                "evicted",
                "prefix_hit_rate",
                "max_stall",
                "inter-tok p99",
                "faults",
                "failed",
            ],
        );
        t.row(vec![
            format!("{}", self.requests_completed),
            format!("{}", self.tokens_generated),
            format!("{:.1}", self.tokens_per_sec()),
            format!("{:.2}", self.token_ms_p50()),
            format!("{:.2}", self.token_ms_p95()),
            format!("{:.2}", self.token_ms_p99()),
            format!("{:.2}", self.ttft_ms_p50()),
            format!("{:.1}", self.mean_queue_depth()),
            format!("{}", self.requests_evicted),
            format!("{:.2}", self.prefix_hit_rate()),
            format!("{}", self.max_decode_stall_steps()),
            format!("{:.2}", self.inter_token_ms_p99()),
            format!("{}", self.step_faults + self.slot_faults),
            format!("{}", self.requests_failed()),
        ]);
        t
    }
}

/// Fixed log2 bucket edges for the latency histograms, in milliseconds:
/// 2^-4 .. 2^14 (62.5 us .. ~16.4 s). Point percentiles hide bimodal
/// stall distributions (a clean 1 ms decode cadence plus occasional 30 ms
/// prefill hiccups averages into a meaningless p95); the bucket counts
/// keep both modes visible in `BENCH_serving.json`.
const HIST_EDGES_MS: [f64; 19] = [
    0.0625, 0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0,
    1024.0, 2048.0, 4096.0, 8192.0, 16384.0,
];

/// Bucket microsecond samples over [`HIST_EDGES_MS`] with Prometheus-style
/// `le` semantics: a sample lands in the first bucket whose edge is `>=`
/// its value in ms; anything beyond the last edge lands in `overflow`.
fn latency_histogram(us: &Samples) -> Json {
    let mut counts = [0usize; HIST_EDGES_MS.len()];
    let mut overflow = 0usize;
    for &v in us.values() {
        let ms = v / 1e3;
        match HIST_EDGES_MS.iter().position(|&e| ms <= e) {
            Some(i) => counts[i] += 1,
            None => overflow += 1,
        }
    }
    json::obj(vec![
        ("le_ms", json::arr(HIST_EDGES_MS.iter().map(|&e| json::num(e)).collect())),
        ("counts", json::arr(counts.iter().map(|&c| json::num(c as f64)).collect())),
        ("overflow", json::num(overflow as f64)),
        ("total", json::num(us.len() as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_and_percentiles() {
        let mut m = ServingMetrics::new();
        // 4 steps of 1000us, each producing 2 tokens -> 8 tokens in 4ms.
        for _ in 0..4 {
            m.record_step(1000.0, 2, 2, 1);
        }
        assert_eq!(m.tokens_generated, 8);
        assert!((m.busy_secs() - 0.004).abs() < 1e-9);
        assert!((m.tokens_per_sec() - 2000.0).abs() < 1e-6);
        assert!((m.token_ms_p50() - 1.0).abs() < 1e-9);
        assert!((m.token_ms_p99() - 1.0).abs() < 1e-9);
        assert!((m.mean_queue_depth() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn prefill_is_tracked_separately_from_decode() {
        let mut m = ServingMetrics::new();
        // 2 prefill calls (16 prompt tokens each; the second finishes a
        // prompt and samples a first token) + 2 decode steps of 1 token.
        m.record_prefill(4000.0, 16, 0, 1, 0);
        m.record_prefill(4000.0, 16, 1, 1, 0);
        m.record_step(1000.0, 1, 1, 0);
        m.record_step(1000.0, 1, 1, 0);
        assert_eq!(m.tokens_prefilled, 32);
        assert_eq!(m.tokens_generated, 3);
        assert_eq!(m.prefill_us.len(), 2);
        assert_eq!(m.step_us.len(), 2);
        // Busy time sums both kinds of engine call.
        assert!((m.busy_secs() - 0.010).abs() < 1e-9);
        // Per-token latency has one 4ms sample (the prefill-produced first
        // token) and two 1ms decode samples; prefill never pollutes p50.
        assert!((m.token_ms_p50() - 1.0).abs() < 1e-9);
        let j = m.to_json();
        assert_eq!(j.req("prefill_calls").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.req("tokens_prefilled").unwrap().as_f64(), Some(32.0));
        assert!((j.req("prefill_ms_p50").unwrap().as_f64().unwrap() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn completions_feed_ttft_and_latency() {
        let mut m = ServingMetrics::new();
        m.record_completion(10_000.0, Some(2_000.0));
        m.record_completion(20_000.0, None);
        assert_eq!(m.requests_completed, 2);
        assert_eq!(m.ttft_us.len(), 1);
        assert!((m.ttft_ms_p50() - 2.0).abs() < 1e-9);
        assert!((m.request_us.mean_us() - 15_000.0).abs() < 1e-9);
    }

    #[test]
    fn json_shape() {
        let mut m = ServingMetrics::new();
        m.record_step(500.0, 1, 1, 0);
        let j = m.to_json();
        assert_eq!(j.req("tokens").unwrap().as_f64(), Some(1.0));
        assert!(j.req("tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);
        // Serializes cleanly.
        assert!(j.to_string().contains("token_ms_p99"));
    }

    #[test]
    fn prefix_reuse_feeds_hit_rate() {
        let mut m = ServingMetrics::new();
        m.record_admission(0, 40);
        m.record_admission(32, 40);
        m.record_admission(32, 40);
        assert_eq!(m.tokens_reused, 64);
        assert_eq!(m.prefix_hits, 2);
        assert_eq!(m.prompt_tokens_admitted, 120);
        assert!((m.prefix_hit_rate() - 64.0 / 120.0).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.req("tokens_reused").unwrap().as_f64(), Some(64.0));
        assert_eq!(j.req("prefix_hits").unwrap().as_f64(), Some(2.0));
        // No admissions: rate is 0, not NaN.
        assert_eq!(ServingMetrics::new().prefix_hit_rate(), 0.0);
    }

    #[test]
    fn decode_stall_and_inter_token_latency() {
        let mut m = ServingMetrics::new();
        // A token every iteration for a while, then a 3-iteration stall
        // (e.g. a long prompt's budget-off prefill burst).
        for _ in 0..10 {
            m.record_decode_token_wait(0, 800.0);
        }
        m.record_decode_token_wait(3, 3200.0);
        assert_eq!(m.max_decode_stall_steps(), 3);
        assert!((m.inter_token_ms_p99() - 3.2).abs() < 1e-9);
        assert_eq!(m.decode_stall_steps.len(), 11);
        let j = m.to_json();
        assert_eq!(j.req("max_decode_stall_steps").unwrap().as_f64(), Some(3.0));
        // No samples: 0, not NaN.
        assert_eq!(ServingMetrics::new().max_decode_stall_steps(), 0);
        assert_eq!(ServingMetrics::new().inter_token_ms_p99(), 0.0);
    }

    #[test]
    fn prefill_share_gauge_and_mixed_steps() {
        let mut m = ServingMetrics::new();
        m.record_token_mix(8, 0); // pure prefill iteration
        m.record_token_mix(4, 4); // composed 50/50 iteration
        m.record_mixed_step();
        m.record_token_mix(0, 8); // pure decode iteration
        m.record_token_mix(0, 0); // fed nothing: no sample
        assert_eq!(m.prefill_share.len(), 3);
        assert!((m.mean_prefill_share() - 0.5).abs() < 1e-9);
        assert_eq!(m.mixed_steps, 1);
        let j = m.to_json();
        assert_eq!(j.req("mixed_steps").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn ttft_splits_into_queue_wait_and_prefill_spread() {
        // Regression (satellite): once prompts split across many budgeted
        // steps, TTFT alone cannot say whether a request waited in the
        // queue or spent the time prefilling — the two halves are recorded
        // separately and sum to TTFT.
        let mut m = ServingMetrics::new();
        m.record_first_token(5_000.0, 1_000.0);
        m.record_completion(20_000.0, Some(6_000.0));
        assert!((m.queue_ms_p50() - 5.0).abs() < 1e-9);
        assert!((m.prefill_spread_ms_p50() - 1.0).abs() < 1e-9);
        assert!((m.queue_ms_p50() + m.prefill_spread_ms_p50() - m.ttft_ms_p50()).abs() < 1e-9);
        let j = m.to_json();
        assert_eq!(j.req("queue_ms_p50").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.req("prefill_spread_ms_p50").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn empty_metrics_are_zero_not_nan() {
        let m = ServingMetrics::new();
        assert_eq!(m.tokens_per_sec(), 0.0);
        assert_eq!(m.token_ms_p99(), 0.0);
        let md = m.table("t").to_markdown();
        assert!(md.contains("### t"));
    }

    #[test]
    fn table_renders_eviction_prefix_and_stall_columns() {
        // Satellite: the columns that used to exist only in JSON.
        let mut m = ServingMetrics::new();
        m.record_eviction();
        m.record_admission(32, 40);
        m.record_decode_token_wait(3, 3200.0);
        let md = m.table("serve").to_markdown();
        for header in ["evicted", "prefix_hit_rate", "max_stall", "inter-tok p99"] {
            assert!(md.contains(header), "missing column {header:?} in:\n{md}");
        }
        assert!(md.contains("0.80"), "hit rate 32/40 renders: \n{md}");
    }

    #[test]
    fn fault_and_shed_counters_export() {
        let mut m = ServingMetrics::new();
        m.record_step_fault();
        m.record_slot_fault();
        m.record_slot_fault();
        m.record_retry();
        m.record_retry();
        m.record_recovery();
        m.record_quarantine();
        m.record_fault_eviction();
        m.record_deadline_shed_queued();
        m.record_deadline_shed_inflight();
        assert_eq!(m.requests_failed(), 3);
        let j = m.to_json();
        assert_eq!(j.req("step_faults").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.req("slot_faults").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.req("retries_scheduled").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.req("slots_recovered").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.req("requests_quarantined").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.req("requests_fault_evicted").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.req("deadline_shed_queued").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.req("deadline_shed_inflight").unwrap().as_f64(), Some(1.0));
        let md = m.table("serve").to_markdown();
        for header in ["faults", "failed"] {
            assert!(md.contains(header), "missing column {header:?} in:\n{md}");
        }
    }

    #[test]
    fn speculation_counters_and_accept_rate() {
        let mut m = ServingMetrics::new();
        // Two verify calls: 4 drafts with 3 accepted, then 4 with 1.
        m.record_verify_call();
        m.record_draft_proposed(4);
        m.record_draft_accepted(3);
        m.record_verify_call();
        m.record_draft_proposed(4);
        m.record_draft_accepted(1);
        assert_eq!(m.draft_tokens_proposed, 8);
        assert_eq!(m.draft_tokens_accepted, 4);
        assert_eq!(m.verify_calls, 2);
        assert!((m.accept_rate() - 0.5).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.req("draft_tokens_proposed").unwrap().as_f64(), Some(8.0));
        assert_eq!(j.req("draft_tokens_accepted").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.req("accept_rate").unwrap().as_f64(), Some(0.5));
        assert_eq!(j.req("verify_calls").unwrap().as_f64(), Some(2.0));
        // Nothing proposed: 0, not NaN.
        assert_eq!(ServingMetrics::new().accept_rate(), 0.0);
    }

    #[test]
    fn latency_histogram_bucket_boundaries() {
        let mut m = ServingMetrics::new();
        // 62.5us = first edge exactly (le semantics: first bucket);
        // 62.6us = just past it (second bucket); 1ms = fifth edge exactly;
        // 20s = beyond the last edge (overflow).
        for us in [62.5, 62.6, 1000.0, 20_000_000.0] {
            m.record_decode_token_wait(0, us);
        }
        let j = m.to_json();
        let h = j.req("histograms").unwrap().req("inter_token_ms").unwrap();
        let counts = h.req("counts").unwrap().as_arr().unwrap();
        let edges = h.req("le_ms").unwrap().as_arr().unwrap();
        assert_eq!(edges.len(), counts.len());
        assert_eq!(edges[0].as_f64(), Some(0.0625));
        assert_eq!(counts[0].as_f64(), Some(1.0));
        assert_eq!(counts[1].as_f64(), Some(1.0));
        assert_eq!(counts[4].as_f64(), Some(1.0));
        assert_eq!(h.req("overflow").unwrap().as_f64(), Some(1.0));
        assert_eq!(h.req("total").unwrap().as_f64(), Some(4.0));
        // Buckets partition the samples.
        let bucketed: f64 = counts.iter().map(|c| c.as_f64().unwrap()).sum();
        assert_eq!(bucketed + 1.0, 4.0);
    }

    #[test]
    fn latency_histogram_empty_case() {
        let j = ServingMetrics::new().to_json();
        let h = j.req("histograms").unwrap().req("ttft_ms").unwrap();
        assert_eq!(h.req("total").unwrap().as_f64(), Some(0.0));
        assert_eq!(h.req("overflow").unwrap().as_f64(), Some(0.0));
        let counts = h.req("counts").unwrap().as_arr().unwrap();
        assert!(counts.iter().all(|c| c.as_f64() == Some(0.0)));
    }
}
