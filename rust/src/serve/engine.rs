//! Decode engines: the batched single-step interface the scheduler drives.
//!
//! [`PjrtEngine`] wraps one `decode_*` artifact (B = 1) or `decode_*_b{N}`
//! artifact (B = N slots) and keeps the KV cache as PJRT literals between
//! steps — zero host round-trips on the steady-state path (see
//! `benches/decode_paths.rs` for the before/after of that optimisation).
//! [`MockEngine`] is a deterministic in-process stand-in whose logits depend
//! only on a slot's token history, so scheduler and sampler behaviour can be
//! tested (and benched) without artifacts, and a request's generation is
//! identical regardless of batch composition.

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::eval::QcfgVec;
use crate::model::Weights;
use crate::runtime::{Executable, Value};
use crate::util::prng::Prng;
use crate::util::timer::Samples;

/// Which decode artifact family to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeVariant {
    Fp,
    QuantNoHad,
    QuantHad,
}

impl DecodeVariant {
    /// The single-slot (B = 1) artifact name.
    pub fn artifact(&self) -> &'static str {
        match self {
            DecodeVariant::Fp => "decode_fp",
            DecodeVariant::QuantNoHad => "decode_nohad",
            DecodeVariant::QuantHad => "decode_had",
        }
    }

    /// The batched artifact name for `batch` slots (`decode_*_b{N}`),
    /// falling back to the scalar name at batch 1.
    pub fn artifact_batched(&self, batch: usize) -> String {
        if batch <= 1 {
            self.artifact().to_string()
        } else {
            format!("{}_b{batch}", self.artifact())
        }
    }
}

/// One decode iteration over a fixed set of KV-cache slots.
///
/// `step` feeds `tokens[b]` at position `pos[b]` into every slot `b` with
/// `active[b]` set and returns per-slot next-token logits. Inactive slots
/// are stepped with a placeholder token at position 0; because the decode
/// graphs mask attention to `idx <= pos`, whatever such a step writes into
/// a free slot's cache is invisible to any future occupant (which starts at
/// `pos = 0` and overwrites from there).
pub trait DecodeEngine {
    /// Number of KV-cache slots (the batch dimension B).
    fn slots(&self) -> usize;

    /// Cache capacity per slot (positions).
    fn max_seq(&self) -> usize;

    /// Advance every slot one token; returns logits per slot (empty vec for
    /// inactive slots is allowed but not required).
    fn step(&mut self, tokens: &[i32], pos: &[i32], active: &[bool]) -> Result<Vec<Vec<f32>>>;

    /// Forget per-slot state when a slot is reused for a new request.
    fn reset_slot(&mut self, slot: usize);
}

// ---------------------------------------------------------------------------
// Shared PJRT decode-artifact binding (used by PjrtEngine and the legacy
// GenerationSession so the input-ABI parsing and literal recycling exist
// exactly once).
// ---------------------------------------------------------------------------

/// Prepared input literals + the index map for one decode artifact.
struct DecodeBinding {
    literals: Vec<xla::Literal>,
    token_idx: usize,
    pos_idx: usize,
    /// Legacy B=1 artifacts take `pos` as a scalar; batched ones as (B,).
    pos_scalar: bool,
    cache_k_idx: usize,
    cache_v_idx: usize,
    n_slots: usize,
    max_seq: usize,
}

impl DecodeBinding {
    /// Bind weights/qcfg/zeroed caches to the artifact's input ABI.
    fn new(exe: &Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let mut values = Vec::with_capacity(exe.spec.inputs.len());
        let (mut token_idx, mut pos_idx, mut ck, mut cv) = (None, None, None, None);
        let mut pos_scalar = false;
        let mut n_slots = 0usize;
        let mut max_seq = 0usize;
        for (i, (name, shape, _)) in exe.spec.inputs.iter().enumerate() {
            let v = match name.as_str() {
                "token" => {
                    token_idx = Some(i);
                    n_slots = shape.first().copied().unwrap_or(1);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "pos" => {
                    pos_idx = Some(i);
                    if shape.is_empty() {
                        pos_scalar = true;
                        Value::ScalarI32(0)
                    } else {
                        Value::I32(vec![0; shape.iter().product()], shape.clone())
                    }
                }
                "cache_k" => {
                    ck = Some(i);
                    max_seq = shape[2];
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "cache_v" => {
                    cv = Some(i);
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "qcfg" => Value::F32(
                    qcfg.ok_or_else(|| anyhow!("{}: needs qcfg", exe.label))?.tensor(),
                ),
                _ => Value::F32(weights.get(name)?.clone()),
            };
            values.push(v);
        }
        let literals = exe.prepare(&values)?;
        if pos_scalar && n_slots != 1 {
            bail!("{}: scalar pos input but {} token slots", exe.label, n_slots);
        }
        Ok(Self {
            literals,
            token_idx: token_idx.ok_or_else(|| anyhow!("no token input"))?,
            pos_idx: pos_idx.ok_or_else(|| anyhow!("no pos input"))?,
            pos_scalar,
            cache_k_idx: ck.ok_or_else(|| anyhow!("no cache_k input"))?,
            cache_v_idx: cv.ok_or_else(|| anyhow!("no cache_v input"))?,
            n_slots,
            max_seq,
        })
    }

    /// Run one decode step: rebuild the token/pos literals, execute, keep
    /// the returned caches as literals (zero host round-trips), return the
    /// flat logits (n_slots * V).
    fn step(&mut self, exe: &Executable, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        if tokens.len() != self.n_slots || pos.len() != self.n_slots {
            bail!(
                "{}: step arity {} / {}, artifact has {} slots",
                exe.label,
                tokens.len(),
                pos.len(),
                self.n_slots
            );
        }
        for (b, &p) in pos.iter().enumerate() {
            if (p as usize) >= self.max_seq {
                bail!("slot {b}: KV cache full ({} positions)", self.max_seq);
            }
        }
        self.literals[self.token_idx] =
            xla::Literal::vec1(tokens).reshape(&[self.n_slots as i64])?;
        self.literals[self.pos_idx] = if self.pos_scalar {
            xla::Literal::scalar(pos[0])
        } else {
            xla::Literal::vec1(pos).reshape(&[self.n_slots as i64])?
        };
        let bufs = exe.run_literals_raw(&self.literals)?;
        let result = bufs[0][0].to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        // outputs: logits, cache_k, cache_v — keep caches as literals.
        let cache_v = parts.pop().ok_or_else(|| anyhow!("missing cache_v"))?;
        let cache_k = parts.pop().ok_or_else(|| anyhow!("missing cache_k"))?;
        let logits_lit = parts.pop().ok_or_else(|| anyhow!("missing logits"))?;
        self.literals[self.cache_k_idx] = cache_k;
        self.literals[self.cache_v_idx] = cache_v;
        Ok(logits_lit.to_vec::<f32>()?)
    }
}

// ---------------------------------------------------------------------------
// PJRT-backed engine
// ---------------------------------------------------------------------------

/// The production engine: one compiled decode artifact, weight + cache
/// literals prepared once, token/pos literals rebuilt per step.
pub struct PjrtEngine {
    exe: Executable,
    bind: DecodeBinding,
    pub step_times: Samples,
}

impl PjrtEngine {
    /// Build from a compiled decode artifact (takes ownership so callers
    /// can move the engine into schedulers/threads without self-reference).
    pub fn new(exe: Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let bind = DecodeBinding::new(&exe, weights, qcfg)?;
        Ok(Self { exe, bind, step_times: Samples::new() })
    }

    pub fn label(&self) -> &str {
        &self.exe.label
    }

    pub fn ms_per_step(&self) -> f64 {
        self.step_times.mean_us() / 1e3
    }
}

impl DecodeEngine for PjrtEngine {
    fn slots(&self) -> usize {
        self.bind.n_slots
    }

    fn max_seq(&self) -> usize {
        self.bind.max_seq
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32], _active: &[bool]) -> Result<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let flat = self.bind.step(&self.exe, tokens, pos)?;
        self.step_times.push(t0.elapsed().as_secs_f64() * 1e6);
        let vocab = flat.len() / self.bind.n_slots.max(1);
        Ok(flat.chunks(vocab).map(|c| c.to_vec()).collect())
    }

    fn reset_slot(&mut self, _slot: usize) {
        // Nothing to do: attention masking (`idx <= pos`) makes a previous
        // occupant's stale cache entries unreachable once the slot restarts
        // at pos = 0.
    }
}

// ---------------------------------------------------------------------------
// Deterministic mock engine (tests + artifact-free benches)
// ---------------------------------------------------------------------------

/// A deterministic fake model. Logits for a slot are a pure function of the
/// slot's token *history* (not of the slot index, the batch composition, or
/// the wall clock), so the same request produces the same generation at any
/// batch size — exactly the property continuous-batching tests need.
///
/// It also asserts the scheduler's contract: a step's `pos[b]` must equal
/// the number of tokens already fed into slot `b`, and reused slots must be
/// reset. Violations are reported as errors instead of silent corruption.
pub struct MockEngine {
    n_slots: usize,
    max_seq: usize,
    vocab: usize,
    history: Vec<Vec<i32>>,
    /// Total engine steps executed (for batching-efficiency assertions).
    pub steps: usize,
}

impl MockEngine {
    pub fn new(slots: usize, max_seq: usize, vocab: usize) -> Self {
        Self { n_slots: slots, max_seq, vocab, history: vec![Vec::new(); slots], steps: 0 }
    }

    /// Deterministic logits from a token history: a pseudo-random base
    /// (hash-seeded, so temperature sampling has texture) plus a strong
    /// peak on the "predicted" next token.
    fn logits_for(history: &[i32], vocab: usize) -> Vec<f32> {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &t in history {
            h = (h ^ t as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        let mut rng = Prng::new(h);
        let mut logits: Vec<f32> = (0..vocab).map(|_| rng.uniform() * 4.0).collect();
        let last = *history.last().unwrap_or(&0) as usize;
        let peak = (last * 31 + history.len() * 7 + 13) % vocab;
        logits[peak] += 8.0;
        logits
    }
}

impl DecodeEngine for MockEngine {
    fn slots(&self) -> usize {
        self.n_slots
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32], active: &[bool]) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != self.n_slots || pos.len() != self.n_slots || active.len() != self.n_slots
        {
            bail!("mock engine: step arity mismatch ({} slots)", self.n_slots);
        }
        self.steps += 1;
        let mut out = Vec::with_capacity(self.n_slots);
        for b in 0..self.n_slots {
            if !active[b] {
                out.push(Vec::new());
                continue;
            }
            if pos[b] as usize != self.history[b].len() {
                bail!(
                    "mock engine: slot {b} stepped at pos {} but holds {} tokens \
                     (scheduler position tracking broken, or slot reused without reset)",
                    pos[b],
                    self.history[b].len()
                );
            }
            if self.history[b].len() >= self.max_seq {
                bail!("mock engine: slot {b} cache full ({} positions)", self.max_seq);
            }
            self.history[b].push(tokens[b]);
            out.push(Self::logits_for(&self.history[b], self.vocab));
        }
        Ok(out)
    }

    fn reset_slot(&mut self, slot: usize) {
        self.history[slot].clear();
    }
}

// ---------------------------------------------------------------------------
// Single-request convenience session (paper Table 6 / Fig. 7 harnesses)
// ---------------------------------------------------------------------------

/// One active generation with its KV cache over a B=1 decode artifact.
/// Kept for the latency harnesses and the legacy `Server`; the batched
/// serving path goes through [`PjrtEngine`] + [`super::Scheduler`]. The
/// artifact binding and step mechanics are shared with [`PjrtEngine`]
/// through [`DecodeBinding`].
pub struct GenerationSession<'e> {
    exe: &'e Executable,
    bind: DecodeBinding,
    pub max_seq: usize,
    pub pos: usize,
    pub step_times: Samples,
}

impl<'e> GenerationSession<'e> {
    pub fn new(exe: &'e Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let bind = DecodeBinding::new(exe, weights, qcfg)?;
        if bind.n_slots != 1 {
            bail!(
                "{}: GenerationSession is single-request; artifact has {} slots \
                 (use PjrtEngine + Scheduler)",
                exe.label,
                bind.n_slots
            );
        }
        let max_seq = bind.max_seq;
        Ok(Self { exe, bind, max_seq, pos: 0, step_times: Samples::new() })
    }

    /// Feed one token, advance the cache, return the logits (V,).
    pub fn step(&mut self, token: u8) -> Result<Vec<f32>> {
        if self.pos >= self.max_seq {
            bail!("KV cache full ({} positions)", self.max_seq);
        }
        let t0 = Instant::now();
        let logits = self.bind.step(self.exe, &[token as i32], &[self.pos as i32])?;
        self.pos += 1;
        self.step_times.push(t0.elapsed().as_secs_f64() * 1e6);
        Ok(logits)
    }

    /// Greedy generation from a byte prompt.
    pub fn generate(&mut self, prompt: &[u8], n_new: usize) -> Result<Vec<u8>> {
        let mut last = Vec::new();
        for &b in prompt {
            last = self.step(b)?;
        }
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            if self.pos >= self.max_seq {
                break;
            }
            let next = super::sampling::argmax(&last) as u8;
            out.push(next);
            last = self.step(next)?;
        }
        Ok(out)
    }

    pub fn ms_per_token(&self) -> f64 {
        self.step_times.mean_us() / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(DecodeVariant::Fp.artifact(), "decode_fp");
        assert_eq!(DecodeVariant::QuantHad.artifact_batched(1), "decode_had");
        assert_eq!(DecodeVariant::QuantNoHad.artifact_batched(8), "decode_nohad_b8");
    }

    #[test]
    fn mock_is_deterministic_and_slot_independent() {
        let mut a = MockEngine::new(2, 16, 64);
        let mut b = MockEngine::new(4, 16, 64);
        // Same history in slot 0 of engine A and slot 3 of engine B.
        let la = a.step(&[7, 9], &[0, 0], &[true, true]).unwrap();
        let lb = b
            .step(&[1, 2, 3, 7], &[0, 0, 0, 0], &[true, true, true, true])
            .unwrap();
        assert_eq!(la[0], lb[3]);
        assert_ne!(la[0], la[1]);
    }

    #[test]
    fn mock_rejects_position_drift() {
        let mut e = MockEngine::new(1, 16, 32);
        e.step(&[5], &[0], &[true]).unwrap();
        // Correct pos is 1; claiming 0 again must fail loudly.
        assert!(e.step(&[6], &[0], &[true]).is_err());
        // After a reset the slot restarts at 0.
        e.reset_slot(0);
        e.step(&[6], &[0], &[true]).unwrap();
    }

    #[test]
    fn mock_enforces_capacity() {
        let mut e = MockEngine::new(1, 2, 8);
        e.step(&[1], &[0], &[true]).unwrap();
        e.step(&[1], &[1], &[true]).unwrap();
        assert!(e.step(&[1], &[2], &[true]).is_err());
    }

    #[test]
    fn mock_inactive_slots_untouched() {
        let mut e = MockEngine::new(2, 8, 16);
        let out = e.step(&[3, 0], &[0, 0], &[true, false]).unwrap();
        assert_eq!(out[1].len(), 0);
        assert_eq!(e.history[1].len(), 0);
        assert_eq!(e.history[0].len(), 1);
    }
}
