//! Decode engines: the batched single-step interface the scheduler drives.
//!
//! [`PjrtEngine`] wraps one `decode_*` artifact (B = 1) or `decode_*_b{N}`
//! artifact (B = N slots) and keeps the KV cache as PJRT literals between
//! steps — zero host round-trips on the steady-state path (see
//! `benches/decode_paths.rs` for the before/after of that optimisation).
//! [`MockEngine`] is a deterministic in-process stand-in whose logits depend
//! only on a slot's token history, so scheduler and sampler behaviour can be
//! tested (and benched) without artifacts, and a request's generation is
//! identical regardless of batch composition.

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::eval::QcfgVec;
use crate::model::Weights;
use crate::runtime::{Executable, Value};
use crate::util::prng::Prng;
use crate::util::timer::Samples;

/// Which decode artifact family to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeVariant {
    Fp,
    QuantNoHad,
    QuantHad,
}

impl DecodeVariant {
    /// The single-slot (B = 1) artifact name.
    pub fn artifact(&self) -> &'static str {
        match self {
            DecodeVariant::Fp => "decode_fp",
            DecodeVariant::QuantNoHad => "decode_nohad",
            DecodeVariant::QuantHad => "decode_had",
        }
    }

    /// The batched artifact name for `batch` slots (`decode_*_b{N}`),
    /// falling back to the scalar name at batch 1.
    pub fn artifact_batched(&self, batch: usize) -> String {
        if batch <= 1 {
            self.artifact().to_string()
        } else {
            format!("{}_b{batch}", self.artifact())
        }
    }

    /// The batched multi-token prefill artifact for `batch` slots consuming
    /// `chunk` prompt tokens per call (`prefill_*_b{N}_t{T}`).
    pub fn artifact_prefill(&self, batch: usize, chunk: usize) -> String {
        let core = match self {
            DecodeVariant::Fp => "prefill_fp",
            DecodeVariant::QuantNoHad => "prefill_nohad",
            DecodeVariant::QuantHad => "prefill_had",
        };
        format!("{core}_b{batch}_t{chunk}")
    }
}

/// One decode iteration over a fixed set of KV-cache slots.
///
/// `step` feeds `tokens[b]` at position `pos[b]` into every slot `b` with
/// `active[b]` set and returns per-slot next-token logits. Inactive slots
/// are stepped with a placeholder token at position 0; because the decode
/// graphs mask attention to `idx <= pos`, whatever such a step writes into
/// a free slot's cache is invisible to any future occupant (which starts at
/// `pos = 0` and overwrites from there).
///
/// `prefill` is the multi-token prompt path: up to [`prefill_chunk`] prompt
/// tokens per slot are consumed in a single call, so time-to-first-token
/// costs `ceil(len/T)` engine calls instead of `len`. Engines without a
/// prefill graph keep the default implementation, which falls back to a
/// loop of single decode steps (same semantics, `len` calls).
pub trait DecodeEngine {
    /// Number of KV-cache slots (the batch dimension B).
    fn slots(&self) -> usize;

    /// Cache capacity per slot (positions).
    fn max_seq(&self) -> usize;

    /// Advance every slot one token; returns logits per slot (empty vec for
    /// inactive slots is allowed but not required).
    fn step(&mut self, tokens: &[i32], pos: &[i32], active: &[bool]) -> Result<Vec<Vec<f32>>>;

    /// Max prompt tokens consumed per `prefill` call. 1 means the engine
    /// has no batched prefill; the scheduler then feeds prompts through the
    /// per-token decode path exactly as before.
    fn prefill_chunk(&self) -> usize {
        1
    }

    /// Feed `tokens[b]` (up to `prefill_chunk()` tokens) into every slot
    /// with `active[b]` set, starting at cache position `pos0[b]`; all fed
    /// KV entries are written and the logits at each slot's last fed
    /// position are returned (empty vec for inactive slots).
    ///
    /// Default: the chunked fallback — a loop of single decode steps, used
    /// when no prefill artifact is available.
    fn prefill(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<f32>>> {
        prefill_by_steps(self, tokens, pos0, active)
    }

    /// Forget per-slot state when a slot is reused for a new request.
    fn reset_slot(&mut self, slot: usize);
}

/// The chunked prefill fallback: feed the chunk through single decode
/// steps. Shared by the trait default and by [`PjrtEngine`] when no prefill
/// artifact was loaded.
pub(crate) fn prefill_by_steps<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    tokens: &[Vec<i32>],
    pos0: &[i32],
    active: &[bool],
) -> Result<Vec<Vec<f32>>> {
    let n = engine.slots();
    if tokens.len() != n || pos0.len() != n || active.len() != n {
        bail!("prefill arity mismatch ({n} slots)");
    }
    let longest = (0..n).filter(|&b| active[b]).map(|b| tokens[b].len()).max().unwrap_or(0);
    let mut out = vec![Vec::new(); n];
    for j in 0..longest {
        let mut toks = vec![0i32; n];
        let mut pos = vec![0i32; n];
        let mut act = vec![false; n];
        for b in 0..n {
            if active[b] && j < tokens[b].len() {
                act[b] = true;
                toks[b] = tokens[b][j];
                pos[b] = pos0[b] + j as i32;
            }
        }
        let mut logits = engine.step(&toks, &pos, &act)?;
        for b in 0..n {
            if act[b] && j + 1 == tokens[b].len() {
                out[b] = std::mem::take(&mut logits[b]);
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Shared PJRT decode-artifact binding (used by PjrtEngine and the legacy
// GenerationSession so the input-ABI parsing and literal recycling exist
// exactly once).
// ---------------------------------------------------------------------------

/// Prepared input literals + the index map for one decode artifact.
struct DecodeBinding {
    literals: Vec<xla::Literal>,
    token_idx: usize,
    pos_idx: usize,
    /// Legacy B=1 artifacts take `pos` as a scalar; batched ones as (B,).
    pos_scalar: bool,
    cache_k_idx: usize,
    cache_v_idx: usize,
    n_slots: usize,
    max_seq: usize,
}

impl DecodeBinding {
    /// Bind weights/qcfg/zeroed caches to the artifact's input ABI.
    fn new(exe: &Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let mut values = Vec::with_capacity(exe.spec.inputs.len());
        let (mut token_idx, mut pos_idx, mut ck, mut cv) = (None, None, None, None);
        let mut pos_scalar = false;
        let mut n_slots = 0usize;
        let mut max_seq = 0usize;
        for (i, (name, shape, _)) in exe.spec.inputs.iter().enumerate() {
            let v = match name.as_str() {
                "token" => {
                    token_idx = Some(i);
                    n_slots = shape.first().copied().unwrap_or(1);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "pos" => {
                    pos_idx = Some(i);
                    if shape.is_empty() {
                        pos_scalar = true;
                        Value::ScalarI32(0)
                    } else {
                        Value::I32(vec![0; shape.iter().product()], shape.clone())
                    }
                }
                "cache_k" => {
                    ck = Some(i);
                    max_seq = shape[2];
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "cache_v" => {
                    cv = Some(i);
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "qcfg" => Value::F32(
                    qcfg.ok_or_else(|| anyhow!("{}: needs qcfg", exe.label))?.tensor(),
                ),
                _ => Value::F32(weights.get(name)?.clone()),
            };
            values.push(v);
        }
        let literals = exe.prepare(&values)?;
        if pos_scalar && n_slots != 1 {
            bail!("{}: scalar pos input but {} token slots", exe.label, n_slots);
        }
        Ok(Self {
            literals,
            token_idx: token_idx.ok_or_else(|| anyhow!("no token input"))?,
            pos_idx: pos_idx.ok_or_else(|| anyhow!("no pos input"))?,
            pos_scalar,
            cache_k_idx: ck.ok_or_else(|| anyhow!("no cache_k input"))?,
            cache_v_idx: cv.ok_or_else(|| anyhow!("no cache_v input"))?,
            n_slots,
            max_seq,
        })
    }

    /// Run one decode step: rebuild the token/pos literals, execute, keep
    /// the returned caches as literals (zero host round-trips), return the
    /// flat logits (n_slots * V).
    fn step(&mut self, exe: &Executable, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        if tokens.len() != self.n_slots || pos.len() != self.n_slots {
            bail!(
                "{}: step arity {} / {}, artifact has {} slots",
                exe.label,
                tokens.len(),
                pos.len(),
                self.n_slots
            );
        }
        for (b, &p) in pos.iter().enumerate() {
            if (p as usize) >= self.max_seq {
                bail!("slot {b}: KV cache full ({} positions)", self.max_seq);
            }
        }
        self.literals[self.token_idx] =
            xla::Literal::vec1(tokens).reshape(&[self.n_slots as i64])?;
        self.literals[self.pos_idx] = if self.pos_scalar {
            xla::Literal::scalar(pos[0])
        } else {
            xla::Literal::vec1(pos).reshape(&[self.n_slots as i64])?
        };
        let bufs = exe.run_literals_raw(&self.literals)?;
        let result = bufs[0][0].to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        // outputs: logits, cache_k, cache_v — keep caches as literals.
        let cache_v = parts.pop().ok_or_else(|| anyhow!("missing cache_v"))?;
        let cache_k = parts.pop().ok_or_else(|| anyhow!("missing cache_k"))?;
        let logits_lit = parts.pop().ok_or_else(|| anyhow!("missing logits"))?;
        self.literals[self.cache_k_idx] = cache_k;
        self.literals[self.cache_v_idx] = cache_v;
        Ok(logits_lit.to_vec::<f32>()?)
    }
}

// ---------------------------------------------------------------------------
// Shared PJRT prefill-artifact binding (prefill_*_b{N}_t{T})
// ---------------------------------------------------------------------------

/// Prepared input literals + index map for one batched prefill artifact.
/// The live KV cache stays owned by the [`DecodeBinding`]; each prefill
/// call borrows it in (as input literals) and hands the updated cache back,
/// so decode and prefill always see one coherent cache.
struct PrefillBinding {
    literals: Vec<xla::Literal>,
    tokens_idx: usize,
    pos_idx: usize,
    n_valid_idx: usize,
    cache_k_idx: usize,
    cache_v_idx: usize,
    n_slots: usize,
    t_chunk: usize,
    max_seq: usize,
}

/// Cheap stand-in literal used while a cache literal is moved between the
/// decode and prefill bindings (never executed).
fn placeholder_literal() -> xla::Literal {
    xla::Literal::scalar(0i32)
}

/// Quant-variant token of a standard artifact label:
/// `"sq-2m/decode_nohad_b4"` -> `Some("nohad")`,
/// `"sq-2m/prefill_fp_b4_t16"` -> `Some("fp")`; `None` for custom labels.
fn label_variant(label: &str) -> Option<&str> {
    let name = label.rsplit('/').next().unwrap_or(label);
    let rest = name.strip_prefix("decode_").or_else(|| name.strip_prefix("prefill_"))?;
    rest.split('_').next()
}

impl PrefillBinding {
    fn new(exe: &Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let mut values = Vec::with_capacity(exe.spec.inputs.len());
        let (mut tok, mut pos, mut nv, mut ck, mut cv) = (None, None, None, None, None);
        let (mut n_slots, mut t_chunk, mut max_seq) = (0usize, 0usize, 0usize);
        for (i, (name, shape, _)) in exe.spec.inputs.iter().enumerate() {
            let v = match name.as_str() {
                "tokens" => {
                    tok = Some(i);
                    n_slots = shape.first().copied().unwrap_or(1);
                    t_chunk = shape.get(1).copied().unwrap_or(1);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "pos" => {
                    pos = Some(i);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "n_valid" => {
                    nv = Some(i);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "cache_k" => {
                    ck = Some(i);
                    max_seq = shape[2];
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "cache_v" => {
                    cv = Some(i);
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "qcfg" => Value::F32(
                    qcfg.ok_or_else(|| anyhow!("{}: needs qcfg", exe.label))?.tensor(),
                ),
                _ => Value::F32(weights.get(name)?.clone()),
            };
            values.push(v);
        }
        let mut literals = exe.prepare(&values)?;
        let cache_k_idx = ck.ok_or_else(|| anyhow!("{}: no cache_k input", exe.label))?;
        let cache_v_idx = cv.ok_or_else(|| anyhow!("{}: no cache_v input", exe.label))?;
        // The zero caches above only exist to satisfy prepare()'s shape
        // validation; the live cache is borrowed in from the decode binding
        // per call, so free them now instead of pinning a second cache.
        literals[cache_k_idx] = placeholder_literal();
        literals[cache_v_idx] = placeholder_literal();
        Ok(Self {
            literals,
            tokens_idx: tok.ok_or_else(|| anyhow!("{}: no tokens input", exe.label))?,
            pos_idx: pos.ok_or_else(|| anyhow!("{}: no pos input", exe.label))?,
            n_valid_idx: nv.ok_or_else(|| anyhow!("{}: no n_valid input", exe.label))?,
            cache_k_idx,
            cache_v_idx,
            n_slots,
            t_chunk,
            max_seq,
        })
    }

    /// Run one prefill call: borrow the live caches from `decode`, feed
    /// `tokens[b]` starting at `pos0[b]` for active slots, return the flat
    /// last-valid-position logits (n_slots * V) and hand the updated caches
    /// back to `decode`. (If execution fails the caches are lost — the
    /// engine is unusable after an error, which the scheduler treats as
    /// fatal anyway.)
    fn step(
        &mut self,
        exe: &Executable,
        decode: &mut DecodeBinding,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
    ) -> Result<Vec<f32>> {
        if tokens.len() != self.n_slots || pos0.len() != self.n_slots {
            bail!(
                "{}: prefill arity {} / {}, artifact has {} slots",
                exe.label,
                tokens.len(),
                pos0.len(),
                self.n_slots
            );
        }
        let mut flat_tokens = vec![0i32; self.n_slots * self.t_chunk];
        let mut pos_vec = vec![0i32; self.n_slots];
        let mut n_valid = vec![0i32; self.n_slots];
        for b in 0..self.n_slots {
            if !active[b] || tokens[b].is_empty() {
                continue;
            }
            if tokens[b].len() > self.t_chunk {
                bail!(
                    "{}: slot {b} fed {} tokens, chunk is {}",
                    exe.label,
                    tokens[b].len(),
                    self.t_chunk
                );
            }
            let end = pos0[b] as usize + tokens[b].len();
            if end > self.max_seq {
                bail!("slot {b}: prefill past KV capacity ({} positions)", self.max_seq);
            }
            flat_tokens[b * self.t_chunk..b * self.t_chunk + tokens[b].len()]
                .copy_from_slice(&tokens[b]);
            pos_vec[b] = pos0[b];
            n_valid[b] = tokens[b].len() as i32;
        }
        self.literals[self.tokens_idx] = xla::Literal::vec1(&flat_tokens)
            .reshape(&[self.n_slots as i64, self.t_chunk as i64])?;
        self.literals[self.pos_idx] =
            xla::Literal::vec1(&pos_vec).reshape(&[self.n_slots as i64])?;
        self.literals[self.n_valid_idx] =
            xla::Literal::vec1(&n_valid).reshape(&[self.n_slots as i64])?;
        // Move the live caches in from the decode binding for this call.
        self.literals[self.cache_k_idx] =
            std::mem::replace(&mut decode.literals[decode.cache_k_idx], placeholder_literal());
        self.literals[self.cache_v_idx] =
            std::mem::replace(&mut decode.literals[decode.cache_v_idx], placeholder_literal());
        let bufs = exe.run_literals_raw(&self.literals)?;
        // Drop the consumed pre-call cache copies immediately — otherwise
        // this binding would pin a second cache-sized literal pair for the
        // engine's whole lifetime.
        self.literals[self.cache_k_idx] = placeholder_literal();
        self.literals[self.cache_v_idx] = placeholder_literal();
        let result = bufs[0][0].to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        let cache_v = parts.pop().ok_or_else(|| anyhow!("missing cache_v"))?;
        let cache_k = parts.pop().ok_or_else(|| anyhow!("missing cache_k"))?;
        let logits_lit = parts.pop().ok_or_else(|| anyhow!("missing logits"))?;
        decode.literals[decode.cache_k_idx] = cache_k;
        decode.literals[decode.cache_v_idx] = cache_v;
        Ok(logits_lit.to_vec::<f32>()?)
    }
}

// ---------------------------------------------------------------------------
// PJRT-backed engine
// ---------------------------------------------------------------------------

/// The production engine: one compiled decode artifact, weight + cache
/// literals prepared once, token/pos literals rebuilt per step. Optionally
/// carries a batched prefill artifact ([`PjrtEngine::with_prefill`]) that
/// consumes `T` prompt tokens per call; without one, `prefill` falls back
/// to the chunked decode loop.
pub struct PjrtEngine {
    exe: Executable,
    bind: DecodeBinding,
    prefill_exe: Option<Executable>,
    prefill_bind: Option<PrefillBinding>,
    pub step_times: Samples,
    pub prefill_times: Samples,
}

impl PjrtEngine {
    /// Build from a compiled decode artifact (takes ownership so callers
    /// can move the engine into schedulers/threads without self-reference).
    pub fn new(exe: Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let bind = DecodeBinding::new(&exe, weights, qcfg)?;
        Ok(Self {
            exe,
            bind,
            prefill_exe: None,
            prefill_bind: None,
            step_times: Samples::new(),
            prefill_times: Samples::new(),
        })
    }

    /// Attach a compiled `prefill_*_b{N}_t{T}` artifact. Its slot count and
    /// cache capacity must match the decode artifact's.
    pub fn with_prefill(
        mut self,
        exe: Executable,
        weights: &Weights,
        qcfg: Option<QcfgVec>,
    ) -> Result<Self> {
        let bind = PrefillBinding::new(&exe, weights, qcfg)?;
        if bind.n_slots != self.bind.n_slots || bind.max_seq != self.bind.max_seq {
            bail!(
                "{}: prefill artifact is {} slots x {} positions, decode is {} x {}",
                exe.label,
                bind.n_slots,
                bind.max_seq,
                self.bind.n_slots,
                self.bind.max_seq
            );
        }
        if bind.t_chunk < 2 {
            bail!("{}: prefill chunk {} gains nothing over decode", exe.label, bind.t_chunk);
        }
        // A prefill graph of a different quant variant would silently write
        // differently-quantized KV entries into the shared cache.
        if let (Some(dv), Some(pv)) =
            (label_variant(&self.exe.label), label_variant(&exe.label))
        {
            if dv != pv {
                bail!(
                    "{}: prefill variant {pv:?} does not match decode variant {dv:?} ({})",
                    exe.label,
                    self.exe.label
                );
            }
        }
        self.prefill_exe = Some(exe);
        self.prefill_bind = Some(bind);
        Ok(self)
    }

    pub fn label(&self) -> &str {
        &self.exe.label
    }

    pub fn ms_per_step(&self) -> f64 {
        self.step_times.mean_us() / 1e3
    }
}

impl DecodeEngine for PjrtEngine {
    fn slots(&self) -> usize {
        self.bind.n_slots
    }

    fn max_seq(&self) -> usize {
        self.bind.max_seq
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32], _active: &[bool]) -> Result<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let flat = self.bind.step(&self.exe, tokens, pos)?;
        self.step_times.push(t0.elapsed().as_secs_f64() * 1e6);
        let vocab = flat.len() / self.bind.n_slots.max(1);
        Ok(flat.chunks(vocab).map(|c| c.to_vec()).collect())
    }

    fn prefill_chunk(&self) -> usize {
        self.prefill_bind.as_ref().map(|p| p.t_chunk).unwrap_or(1)
    }

    fn prefill(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<f32>>> {
        if self.prefill_bind.is_none() {
            return prefill_by_steps(self, tokens, pos0, active);
        }
        if active.len() != self.bind.n_slots {
            bail!("prefill arity mismatch ({} slots)", self.bind.n_slots);
        }
        let t0 = Instant::now();
        let pb = self.prefill_bind.as_mut().expect("checked above");
        let pexe = self.prefill_exe.as_ref().expect("set with binding");
        let flat = pb.step(pexe, &mut self.bind, tokens, pos0, active)?;
        self.prefill_times.push(t0.elapsed().as_secs_f64() * 1e6);
        let vocab = flat.len() / pb.n_slots.max(1);
        let mut out = Vec::with_capacity(pb.n_slots);
        for (b, lane) in flat.chunks(vocab).enumerate() {
            if active[b] && !tokens[b].is_empty() {
                out.push(lane.to_vec());
            } else {
                out.push(Vec::new());
            }
        }
        Ok(out)
    }

    fn reset_slot(&mut self, _slot: usize) {
        // Nothing to do: attention masking (`idx <= pos`) makes a previous
        // occupant's stale cache entries unreachable once the slot restarts
        // at pos = 0.
    }
}

// ---------------------------------------------------------------------------
// Deterministic mock engine (tests + artifact-free benches)
// ---------------------------------------------------------------------------

/// A deterministic fake model. Logits for a slot are a pure function of the
/// slot's token *history* (not of the slot index, the batch composition, or
/// the wall clock), so the same request produces the same generation at any
/// batch size — exactly the property continuous-batching tests need.
///
/// It also asserts the scheduler's contract: a step's `pos[b]` must equal
/// the number of tokens already fed into slot `b`, and reused slots must be
/// reset. Violations are reported as errors instead of silent corruption.
pub struct MockEngine {
    n_slots: usize,
    max_seq: usize,
    vocab: usize,
    history: Vec<Vec<i32>>,
    chunk: usize,
    /// Total decode steps executed (for batching-efficiency assertions).
    pub steps: usize,
    /// Total batched prefill calls executed (a prompt of `len` tokens must
    /// cost exactly `ceil(len/chunk)` of these — the TTFT acceptance check).
    pub prefill_calls: usize,
}

impl MockEngine {
    pub fn new(slots: usize, max_seq: usize, vocab: usize) -> Self {
        Self {
            n_slots: slots,
            max_seq,
            vocab,
            history: vec![Vec::new(); slots],
            chunk: 1,
            steps: 0,
            prefill_calls: 0,
        }
    }

    /// Pretend to be an engine with a `T`-token prefill graph (chunk 1 =
    /// no batched prefill, the default).
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Self {
        self.chunk = chunk.max(1);
        self
    }

    /// Deterministic logits from a token history: a pseudo-random base
    /// (hash-seeded, so temperature sampling has texture) plus a strong
    /// peak on the "predicted" next token.
    fn logits_for(history: &[i32], vocab: usize) -> Vec<f32> {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &t in history {
            h = (h ^ t as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        let mut rng = Prng::new(h);
        let mut logits: Vec<f32> = (0..vocab).map(|_| rng.uniform() * 4.0).collect();
        let last = *history.last().unwrap_or(&0) as usize;
        let peak = (last * 31 + history.len() * 7 + 13) % vocab;
        logits[peak] += 8.0;
        logits
    }
}

impl DecodeEngine for MockEngine {
    fn slots(&self) -> usize {
        self.n_slots
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32], active: &[bool]) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != self.n_slots || pos.len() != self.n_slots || active.len() != self.n_slots
        {
            bail!("mock engine: step arity mismatch ({} slots)", self.n_slots);
        }
        self.steps += 1;
        let mut out = Vec::with_capacity(self.n_slots);
        for b in 0..self.n_slots {
            if !active[b] {
                out.push(Vec::new());
                continue;
            }
            if pos[b] as usize != self.history[b].len() {
                bail!(
                    "mock engine: slot {b} stepped at pos {} but holds {} tokens \
                     (scheduler position tracking broken, or slot reused without reset)",
                    pos[b],
                    self.history[b].len()
                );
            }
            if self.history[b].len() >= self.max_seq {
                bail!("mock engine: slot {b} cache full ({} positions)", self.max_seq);
            }
            self.history[b].push(tokens[b]);
            out.push(Self::logits_for(&self.history[b], self.vocab));
        }
        Ok(out)
    }

    fn prefill_chunk(&self) -> usize {
        self.chunk
    }

    fn prefill(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != self.n_slots || pos0.len() != self.n_slots || active.len() != self.n_slots
        {
            bail!("mock engine: prefill arity mismatch ({} slots)", self.n_slots);
        }
        self.prefill_calls += 1;
        let mut out = Vec::with_capacity(self.n_slots);
        for b in 0..self.n_slots {
            if !active[b] || tokens[b].is_empty() {
                out.push(Vec::new());
                continue;
            }
            if tokens[b].len() > self.chunk {
                bail!(
                    "mock engine: slot {b} fed {} prefill tokens, chunk is {}",
                    tokens[b].len(),
                    self.chunk
                );
            }
            if pos0[b] as usize != self.history[b].len() {
                bail!(
                    "mock engine: slot {b} prefilled at pos {} but holds {} tokens \
                     (scheduler position tracking broken, or slot reused without reset)",
                    pos0[b],
                    self.history[b].len()
                );
            }
            if self.history[b].len() + tokens[b].len() > self.max_seq {
                bail!("mock engine: slot {b} prefill past cache ({} positions)", self.max_seq);
            }
            self.history[b].extend_from_slice(&tokens[b]);
            out.push(Self::logits_for(&self.history[b], self.vocab));
        }
        Ok(out)
    }

    fn reset_slot(&mut self, slot: usize) {
        self.history[slot].clear();
    }
}

// ---------------------------------------------------------------------------
// Single-request convenience session (paper Table 6 / Fig. 7 harnesses)
// ---------------------------------------------------------------------------

/// One active generation with its KV cache over a B=1 decode artifact.
/// Kept for the latency harnesses and the legacy `Server`; the batched
/// serving path goes through [`PjrtEngine`] + [`super::Scheduler`]. The
/// artifact binding and step mechanics are shared with [`PjrtEngine`]
/// through [`DecodeBinding`].
pub struct GenerationSession<'e> {
    exe: &'e Executable,
    bind: DecodeBinding,
    pub max_seq: usize,
    pub pos: usize,
    pub step_times: Samples,
}

impl<'e> GenerationSession<'e> {
    pub fn new(exe: &'e Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let bind = DecodeBinding::new(exe, weights, qcfg)?;
        if bind.n_slots != 1 {
            bail!(
                "{}: GenerationSession is single-request; artifact has {} slots \
                 (use PjrtEngine + Scheduler)",
                exe.label,
                bind.n_slots
            );
        }
        let max_seq = bind.max_seq;
        Ok(Self { exe, bind, max_seq, pos: 0, step_times: Samples::new() })
    }

    /// Feed one token, advance the cache, return the logits (V,).
    pub fn step(&mut self, token: u8) -> Result<Vec<f32>> {
        if self.pos >= self.max_seq {
            bail!("KV cache full ({} positions)", self.max_seq);
        }
        let t0 = Instant::now();
        let logits = self.bind.step(self.exe, &[token as i32], &[self.pos as i32])?;
        self.pos += 1;
        self.step_times.push(t0.elapsed().as_secs_f64() * 1e6);
        Ok(logits)
    }

    /// Greedy generation from a byte prompt.
    pub fn generate(&mut self, prompt: &[u8], n_new: usize) -> Result<Vec<u8>> {
        let mut last = Vec::new();
        for &b in prompt {
            last = self.step(b)?;
        }
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            if self.pos >= self.max_seq {
                break;
            }
            let next = super::sampling::argmax(&last) as u8;
            out.push(next);
            last = self.step(next)?;
        }
        Ok(out)
    }

    pub fn ms_per_token(&self) -> f64 {
        self.step_times.mean_us() / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(DecodeVariant::Fp.artifact(), "decode_fp");
        assert_eq!(DecodeVariant::QuantHad.artifact_batched(1), "decode_had");
        assert_eq!(DecodeVariant::QuantNoHad.artifact_batched(8), "decode_nohad_b8");
    }

    #[test]
    fn mock_is_deterministic_and_slot_independent() {
        let mut a = MockEngine::new(2, 16, 64);
        let mut b = MockEngine::new(4, 16, 64);
        // Same history in slot 0 of engine A and slot 3 of engine B.
        let la = a.step(&[7, 9], &[0, 0], &[true, true]).unwrap();
        let lb = b
            .step(&[1, 2, 3, 7], &[0, 0, 0, 0], &[true, true, true, true])
            .unwrap();
        assert_eq!(la[0], lb[3]);
        assert_ne!(la[0], la[1]);
    }

    #[test]
    fn mock_rejects_position_drift() {
        let mut e = MockEngine::new(1, 16, 32);
        e.step(&[5], &[0], &[true]).unwrap();
        // Correct pos is 1; claiming 0 again must fail loudly.
        assert!(e.step(&[6], &[0], &[true]).is_err());
        // After a reset the slot restarts at 0.
        e.reset_slot(0);
        e.step(&[6], &[0], &[true]).unwrap();
    }

    #[test]
    fn mock_enforces_capacity() {
        let mut e = MockEngine::new(1, 2, 8);
        e.step(&[1], &[0], &[true]).unwrap();
        e.step(&[1], &[1], &[true]).unwrap();
        assert!(e.step(&[1], &[2], &[true]).is_err());
    }

    #[test]
    fn mock_inactive_slots_untouched() {
        let mut e = MockEngine::new(2, 8, 16);
        let out = e.step(&[3, 0], &[0, 0], &[true, false]).unwrap();
        assert_eq!(out[1].len(), 0);
        assert_eq!(e.history[1].len(), 0);
        assert_eq!(e.history[0].len(), 1);
    }

    #[test]
    fn prefill_artifact_names() {
        assert_eq!(DecodeVariant::Fp.artifact_prefill(4, 16), "prefill_fp_b4_t16");
        assert_eq!(DecodeVariant::QuantHad.artifact_prefill(8, 64), "prefill_had_b8_t64");
    }

    #[test]
    fn label_variant_extraction() {
        assert_eq!(label_variant("sq-2m/decode_nohad_b4"), Some("nohad"));
        assert_eq!(label_variant("sq-2m/prefill_fp_b4_t16"), Some("fp"));
        assert_eq!(label_variant("decode_had"), Some("had"));
        assert_eq!(label_variant("sq-2m/fwd_eval_nohad"), None);
    }

    #[test]
    fn mock_prefill_equals_step_loop() {
        // One prefill call == the same tokens fed one step at a time: same
        // final logits, same history (mock logits are a pure function of
        // history, mirroring the L2 graph equivalence proven in pytest).
        let prompt = [5i32, 9, 2, 7, 1];
        let mut a = MockEngine::new(2, 32, 64).with_prefill_chunk(8);
        let la = a
            .prefill(&[prompt.to_vec(), Vec::new()], &[0, 0], &[true, false])
            .unwrap();
        let mut b = MockEngine::new(2, 32, 64);
        let mut lb = Vec::new();
        for (j, &t) in prompt.iter().enumerate() {
            lb = b.step(&[t, 0], &[j as i32, 0], &[true, false]).unwrap();
        }
        assert_eq!(la[0], lb[0]);
        assert_eq!(la[1].len(), 0);
        assert_eq!(a.history[0], b.history[0]);
        assert_eq!(a.prefill_calls, 1);
        assert_eq!(a.steps, 0);
    }

    #[test]
    fn default_prefill_falls_back_to_decode_steps() {
        // An engine without a prefill graph (chunk 1) uses the trait's
        // step-loop fallback — and must produce the identical result.
        let prompt = [3i32, 11, 4];
        let mut a = MockEngine::new(1, 16, 32);
        assert_eq!(a.prefill_chunk(), 1);
        // Route through the fallback explicitly (MockEngine's own override
        // would short-circuit it).
        let la = super::prefill_by_steps(&mut a, &[prompt.to_vec()], &[0], &[true]).unwrap();
        let mut b = MockEngine::new(1, 16, 32).with_prefill_chunk(4);
        let lb = b.prefill(&[prompt.to_vec()], &[0], &[true]).unwrap();
        assert_eq!(la[0], lb[0]);
        assert_eq!(a.steps, 3);
        assert_eq!(b.prefill_calls, 1);
    }

    #[test]
    fn mock_prefill_rejects_oversized_chunk_and_position_drift() {
        let mut e = MockEngine::new(1, 16, 32).with_prefill_chunk(2);
        assert!(e.prefill(&[vec![1, 2, 3]], &[0], &[true]).is_err());
        e.prefill(&[vec![1, 2]], &[0], &[true]).unwrap();
        // pos0 must equal the tokens already held.
        assert!(e.prefill(&[vec![3]], &[0], &[true]).is_err());
        e.reset_slot(0);
        e.prefill(&[vec![3]], &[0], &[true]).unwrap();
    }

    #[test]
    fn mock_prefill_enforces_capacity() {
        let mut e = MockEngine::new(1, 3, 8).with_prefill_chunk(4);
        assert!(e.prefill(&[vec![1, 2, 3, 4]], &[0], &[true]).is_err());
        e.prefill(&[vec![1, 2, 3]], &[0], &[true]).unwrap();
    }
}
