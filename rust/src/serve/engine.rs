//! Decode engines: the batched single-step interface the scheduler drives.
//!
//! [`PjrtEngine`] wraps one `decode_*` artifact (B = 1) or `decode_*_b{N}`
//! artifact (B = N slots) and keeps the KV cache as PJRT literals between
//! steps — zero host round-trips on the steady-state path (see
//! `benches/decode_paths.rs` for the before/after of that optimisation).
//! [`MockEngine`] is a deterministic in-process stand-in whose logits depend
//! only on a slot's token history, so scheduler and sampler behaviour can be
//! tested (and benched) without artifacts, and a request's generation is
//! identical regardless of batch composition. [`FaultInjector`] wraps any
//! engine with a seeded deterministic fault schedule ([`ServeError`]
//! transient/per-slot failures injected *before* the inner call runs), the
//! chaos harness the scheduler's error kernel is tested and benched
//! against.

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::eval::QcfgVec;
use crate::model::Weights;
use crate::runtime::{Executable, Value};
use crate::util::prng::Prng;
use crate::util::timer::Samples;

/// Structured serving-failure taxonomy — the scheduler's error kernel
/// classifies every engine `Err` by downcasting to this type.
///
/// * [`ServeError::Transient`] — the whole engine call failed but the
///   engine is still usable and **no slot advanced**; the error kernel
///   retries the step after a deterministic backoff and, on retry
///   exhaustion, evicts the participants to the queue front for a warm
///   restart.
/// * [`ServeError::Slot`] — one request is to blame (again with no slot
///   advanced); the kernel retries that request alone and quarantines it
///   after `retry_budget` individual faults.
/// * [`ServeError::Fatal`] — the engine is unusable (e.g. a PJRT
///   execution failure loses the KV caches). Propagates.
///
/// Errors that are **not** a `ServeError` also propagate: a real engine
/// bug (arity mismatch, position drift, table corruption) must keep
/// aborting loudly instead of being retried into silence.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Step-wide transient fault: retryable, every call participant
    /// affected.
    Transient { what: String },
    /// Per-slot fault: retryable, request in `slot` blamed.
    Slot { slot: usize, what: String },
    /// Unrecoverable engine failure.
    Fatal { what: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Transient { what } => write!(f, "transient engine fault: {what}"),
            ServeError::Slot { slot, what } => write!(f, "slot {slot} fault: {what}"),
            ServeError::Fatal { what } => write!(f, "fatal engine fault: {what}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Which decode artifact family to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeVariant {
    Fp,
    QuantNoHad,
    QuantHad,
}

impl DecodeVariant {
    /// The single-slot (B = 1) artifact name.
    pub fn artifact(&self) -> &'static str {
        match self {
            DecodeVariant::Fp => "decode_fp",
            DecodeVariant::QuantNoHad => "decode_nohad",
            DecodeVariant::QuantHad => "decode_had",
        }
    }

    /// The batched artifact name for `batch` slots (`decode_*_b{N}`),
    /// falling back to the scalar name at batch 1.
    pub fn artifact_batched(&self, batch: usize) -> String {
        if batch <= 1 {
            self.artifact().to_string()
        } else {
            format!("{}_b{batch}", self.artifact())
        }
    }

    /// The batched multi-token prefill artifact for `batch` slots consuming
    /// `chunk` prompt tokens per call (`prefill_*_b{N}_t{T}`).
    pub fn artifact_prefill(&self, batch: usize, chunk: usize) -> String {
        let core = match self {
            DecodeVariant::Fp => "prefill_fp",
            DecodeVariant::QuantNoHad => "prefill_nohad",
            DecodeVariant::QuantHad => "prefill_had",
        };
        format!("{core}_b{batch}_t{chunk}")
    }

    /// The paged (block-pool KV cache) decode artifact for `batch` slots
    /// (`decode_*_paged_b{N}`).
    pub fn artifact_paged(&self, batch: usize) -> String {
        format!("{}_paged_b{batch}", self.artifact())
    }

    /// The paged batched prefill artifact (`prefill_*_paged_b{N}_t{T}`).
    pub fn artifact_prefill_paged(&self, batch: usize, chunk: usize) -> String {
        let core = match self {
            DecodeVariant::Fp => "prefill_fp",
            DecodeVariant::QuantNoHad => "prefill_nohad",
            DecodeVariant::QuantHad => "prefill_had",
        };
        format!("{core}_paged_b{batch}_t{chunk}")
    }
}

/// One decode iteration over a fixed set of KV-cache slots.
///
/// `step` feeds `tokens[b]` at position `pos[b]` into every slot `b` with
/// `active[b]` set and returns per-slot next-token logits. Inactive slots
/// are stepped with a placeholder token at position 0; because the decode
/// graphs mask attention to `idx <= pos`, whatever such a step writes into
/// a free slot's cache is invisible to any future occupant (which starts at
/// `pos = 0` and overwrites from there).
///
/// `prefill` is the multi-token prompt path: up to [`prefill_chunk`] prompt
/// tokens per slot are consumed in a single call, so time-to-first-token
/// costs `ceil(len/T)` engine calls instead of `len`. Engines without a
/// prefill graph keep the default implementation, which falls back to a
/// loop of single decode steps (same semantics, `len` calls).
pub trait DecodeEngine {
    /// Number of KV-cache slots (the batch dimension B).
    fn slots(&self) -> usize;

    /// Cache capacity per slot (positions).
    fn max_seq(&self) -> usize;

    /// Advance every slot one token; returns logits per slot (empty vec for
    /// inactive slots is allowed but not required).
    fn step(&mut self, tokens: &[i32], pos: &[i32], active: &[bool]) -> Result<Vec<Vec<f32>>>;

    /// Max prompt tokens consumed per `prefill` call. 1 means the engine
    /// has no batched prefill; the scheduler then feeds prompts through the
    /// per-token decode path exactly as before.
    fn prefill_chunk(&self) -> usize {
        1
    }

    /// Feed `tokens[b]` (up to `prefill_chunk()` tokens) into every slot
    /// with `active[b]` set, starting at cache position `pos0[b]`; all fed
    /// KV entries are written and the logits at each slot's last fed
    /// position are returned (empty vec for inactive slots).
    ///
    /// Default: the chunked fallback — a loop of single decode steps, used
    /// when no prefill artifact is available.
    fn prefill(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<f32>>> {
        prefill_by_steps(self, tokens, pos0, active)
    }

    /// Forget per-slot state when a slot is reused for a new request.
    fn reset_slot(&mut self, slot: usize);

    // -- paged KV cache (block-pool) path ---------------------------------

    /// `Some(block_size)` when the engine's KV cache is a pool of
    /// `block_size`-token physical pages addressed through per-slot block
    /// tables (`step_paged` / `prefill_paged`); `None` for dense engines.
    fn kv_block_size(&self) -> Option<usize> {
        None
    }

    /// Physical pages in the engine's pool (0 for dense engines). Table
    /// entries `>= kv_blocks()` are the "unallocated page" sentinel: writes
    /// through them are dropped by the graph and reads are clipped (but
    /// masked off by `idx <= pos` anyway).
    fn kv_blocks(&self) -> usize {
        0
    }

    /// KV-cache storage width in bits per element (16 = full precision).
    /// Engines whose cache entries are quantized on write report the real
    /// width here so the scheduler and CLI can account page-byte budgets
    /// honestly (`--kv-bits`, [`crate::serve::blocks::kv_memory_bytes`]).
    fn kv_bits(&self) -> f32 {
        16.0
    }

    /// One decode step over a paged cache: like `step`, plus `tables[b]` —
    /// slot `b`'s block table, padded to the logical page count with the
    /// `kv_blocks()` sentinel (inactive slots: all-sentinel rows, so they
    /// can never scribble on someone else's pages).
    fn step_paged(
        &mut self,
        _tokens: &[i32],
        _pos: &[i32],
        _active: &[bool],
        _tables: &[Vec<i32>],
    ) -> Result<Vec<Vec<f32>>> {
        bail!("engine has no paged KV path")
    }

    /// Paged twin of `prefill`. Default: the chunked fallback — a loop of
    /// single `step_paged` calls, used when no paged prefill artifact is
    /// available.
    fn prefill_paged(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
        tables: &[Vec<i32>],
    ) -> Result<Vec<Vec<f32>>> {
        prefill_paged_by_steps(self, tokens, pos0, active, tables)
    }

    /// A freshly admitted request was handed `cached` tokens of
    /// already-resident shared prefix pages (prefix cache hit): `table` is
    /// its padded block-table row, whose leading pages hold the cached KV
    /// entries, and the scheduler will start feeding at position `cached`.
    /// Called after `reset_slot`.
    ///
    /// Default: no-op — the paged PJRT graphs gather KV by block table, so
    /// aliased tables read shared physical pages with no engine-side state
    /// to fix up (the pytest scattered-table cases cover exactly this).
    /// [`MockEngine`] overrides it to rebuild the slot's token history from
    /// the physical pages, so its per-step content assertions keep working
    /// across shared admissions.
    fn adopt_prefix(&mut self, _slot: usize, _table: &[i32], _cached: usize) -> Result<()> {
        Ok(())
    }

    // -- speculative decoding (draft / verify / rewind) --------------------

    /// Verify a batch of drafted continuations in one call: feed
    /// `tokens[b]` into every slot with `active[b]` set starting at cache
    /// position `pos0[b]`, and return **one logits row per fed token** —
    /// `out[b][i]` is the next-token distribution after `tokens[b][..=i]`,
    /// exactly what `tokens[b].len()` sequential [`step`](Self::step) calls
    /// would have produced. The scheduler samples through these rows left
    /// to right and keeps the longest draft prefix the sampler agrees with
    /// plus one free correction token; trailing rows past the first
    /// disagreement are simply discarded (and the cache rewound).
    ///
    /// Default: a loop of single decode steps keeping every row — the same
    /// ragged fallback as `prefill`, except `prefill` only returns the last
    /// row. Engines with a multi-token graph can do this in
    /// `ceil(k/chunk)`-ish calls instead.
    fn verify(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        verify_by_steps(self, tokens, pos0, active)
    }

    /// Paged twin of [`verify`](Self::verify).
    fn verify_paged(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
        tables: &[Vec<i32>],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        verify_paged_by_steps(self, tokens, pos0, active, tables)
    }

    /// Forget cache state past `new_len` tokens in `slot` — the rollback
    /// half of speculative decoding, called after a verify pass rejected a
    /// draft suffix. `table` is the slot's block-table row *after* the
    /// scheduler's own page rewind (dense engines receive an empty slice).
    ///
    /// Default: no-op, which is sound for attention-masked caches — the
    /// decode graphs mask attention to `idx <= pos`, so stale KV entries
    /// beyond the rewound position are unreachable and the next write at
    /// that position overwrites them (the same argument that makes
    /// placeholder writes into free slots safe). Engines that keep
    /// positional side state (the mock's history hash) must override.
    fn rewind(&mut self, _slot: usize, _new_len: usize, _table: &[i32]) -> Result<()> {
        Ok(())
    }
}

/// The chunked prefill fallback: feed the chunk through single decode
/// steps. Shared by the trait default and by [`PjrtEngine`] when no prefill
/// artifact was loaded.
pub(crate) fn prefill_by_steps<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    tokens: &[Vec<i32>],
    pos0: &[i32],
    active: &[bool],
) -> Result<Vec<Vec<f32>>> {
    let n = engine.slots();
    if tokens.len() != n || pos0.len() != n || active.len() != n {
        bail!("prefill arity mismatch ({n} slots)");
    }
    let longest = (0..n).filter(|&b| active[b]).map(|b| tokens[b].len()).max().unwrap_or(0);
    let mut out = vec![Vec::new(); n];
    for j in 0..longest {
        let mut toks = vec![0i32; n];
        let mut pos = vec![0i32; n];
        let mut act = vec![false; n];
        for b in 0..n {
            if active[b] && j < tokens[b].len() {
                act[b] = true;
                toks[b] = tokens[b][j];
                pos[b] = pos0[b] + j as i32;
            }
        }
        let mut logits = engine.step(&toks, &pos, &act)?;
        for b in 0..n {
            if act[b] && j + 1 == tokens[b].len() {
                out[b] = std::mem::take(&mut logits[b]);
            }
        }
    }
    Ok(out)
}

/// The paged chunked-prefill fallback: feed the chunk through single
/// `step_paged` calls. Shared by the trait default and by [`PjrtEngine`]
/// when no paged prefill artifact was loaded.
pub(crate) fn prefill_paged_by_steps<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    tokens: &[Vec<i32>],
    pos0: &[i32],
    active: &[bool],
    tables: &[Vec<i32>],
) -> Result<Vec<Vec<f32>>> {
    let n = engine.slots();
    if tokens.len() != n || pos0.len() != n || active.len() != n || tables.len() != n {
        bail!("paged prefill arity mismatch ({n} slots)");
    }
    let longest = (0..n).filter(|&b| active[b]).map(|b| tokens[b].len()).max().unwrap_or(0);
    let mut out = vec![Vec::new(); n];
    for j in 0..longest {
        let mut toks = vec![0i32; n];
        let mut pos = vec![0i32; n];
        let mut act = vec![false; n];
        for b in 0..n {
            if active[b] && j < tokens[b].len() {
                act[b] = true;
                toks[b] = tokens[b][j];
                pos[b] = pos0[b] + j as i32;
            }
        }
        let mut logits = engine.step_paged(&toks, &pos, &act, tables)?;
        for b in 0..n {
            if act[b] && j + 1 == tokens[b].len() {
                out[b] = std::mem::take(&mut logits[b]);
            }
        }
    }
    Ok(out)
}

/// The verify fallback: feed each slot's draft window through single decode
/// steps, keeping **every** per-token logits row (unlike the prefill
/// fallbacks, which only keep the last). Shared by the trait default so any
/// `DecodeEngine` supports speculative verification unchanged.
pub(crate) fn verify_by_steps<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    tokens: &[Vec<i32>],
    pos0: &[i32],
    active: &[bool],
) -> Result<Vec<Vec<Vec<f32>>>> {
    let n = engine.slots();
    if tokens.len() != n || pos0.len() != n || active.len() != n {
        bail!("verify arity mismatch ({n} slots)");
    }
    let longest = (0..n).filter(|&b| active[b]).map(|b| tokens[b].len()).max().unwrap_or(0);
    let mut out = vec![Vec::new(); n];
    for j in 0..longest {
        let mut toks = vec![0i32; n];
        let mut pos = vec![0i32; n];
        let mut act = vec![false; n];
        for b in 0..n {
            if active[b] && j < tokens[b].len() {
                act[b] = true;
                toks[b] = tokens[b][j];
                pos[b] = pos0[b] + j as i32;
            }
        }
        let mut logits = engine.step(&toks, &pos, &act)?;
        for b in 0..n {
            if act[b] {
                out[b].push(std::mem::take(&mut logits[b]));
            }
        }
    }
    Ok(out)
}

/// Paged twin of [`verify_by_steps`].
pub(crate) fn verify_paged_by_steps<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    tokens: &[Vec<i32>],
    pos0: &[i32],
    active: &[bool],
    tables: &[Vec<i32>],
) -> Result<Vec<Vec<Vec<f32>>>> {
    let n = engine.slots();
    if tokens.len() != n || pos0.len() != n || active.len() != n || tables.len() != n {
        bail!("paged verify arity mismatch ({n} slots)");
    }
    let longest = (0..n).filter(|&b| active[b]).map(|b| tokens[b].len()).max().unwrap_or(0);
    let mut out = vec![Vec::new(); n];
    for j in 0..longest {
        let mut toks = vec![0i32; n];
        let mut pos = vec![0i32; n];
        let mut act = vec![false; n];
        for b in 0..n {
            if active[b] && j < tokens[b].len() {
                act[b] = true;
                toks[b] = tokens[b][j];
                pos[b] = pos0[b] + j as i32;
            }
        }
        let mut logits = engine.step_paged(&toks, &pos, &act, tables)?;
        for b in 0..n {
            if act[b] {
                out[b].push(std::mem::take(&mut logits[b]));
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Shared PJRT decode-artifact binding (used by PjrtEngine and the legacy
// GenerationSession so the input-ABI parsing and literal recycling exist
// exactly once).
// ---------------------------------------------------------------------------

/// Prepared input literals + the index map for one decode artifact.
struct DecodeBinding {
    literals: Vec<xla::Literal>,
    token_idx: usize,
    pos_idx: usize,
    /// Legacy B=1 artifacts take `pos` as a scalar; batched ones as (B,).
    pos_scalar: bool,
    /// Paged (`decode_*_paged_b{N}`) artifacts take a per-slot block table.
    table_idx: Option<usize>,
    cache_k_idx: usize,
    cache_v_idx: usize,
    n_slots: usize,
    max_seq: usize,
    /// Paged layout: physical pages in the pool / tokens per page / table
    /// columns. Zero for dense artifacts.
    n_blocks: usize,
    block_size: usize,
    n_logical: usize,
}

impl DecodeBinding {
    /// Bind weights/qcfg/zeroed caches to the artifact's input ABI.
    fn new(exe: &Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let mut values = Vec::with_capacity(exe.spec.inputs.len());
        let (mut token_idx, mut pos_idx, mut table_idx, mut ck, mut cv) =
            (None, None, None, None, None);
        let mut pos_scalar = false;
        let mut n_slots = 0usize;
        let mut cache_dims: Vec<usize> = Vec::new();
        let mut n_logical = 0usize;
        for (i, (name, shape, _)) in exe.spec.inputs.iter().enumerate() {
            let v = match name.as_str() {
                "token" => {
                    token_idx = Some(i);
                    n_slots = shape.first().copied().unwrap_or(1);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "pos" => {
                    pos_idx = Some(i);
                    if shape.is_empty() {
                        pos_scalar = true;
                        Value::ScalarI32(0)
                    } else {
                        Value::I32(vec![0; shape.iter().product()], shape.clone())
                    }
                }
                "block_table" => {
                    table_idx = Some(i);
                    n_logical = shape.get(1).copied().unwrap_or(0);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "cache_k" => {
                    ck = Some(i);
                    cache_dims = shape.clone();
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "cache_v" => {
                    cv = Some(i);
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "qcfg" => Value::F32(
                    qcfg.ok_or_else(|| anyhow!("{}: needs qcfg", exe.label))?.tensor(),
                ),
                _ => Value::F32(weights.get(name)?.clone()),
            };
            values.push(v);
        }
        let literals = exe.prepare(&values)?;
        if pos_scalar && n_slots != 1 {
            bail!("{}: scalar pos input but {} token slots", exe.label, n_slots);
        }
        if cache_dims.len() < 3 {
            bail!("{}: no (or malformed) cache_k input", exe.label);
        }
        // Dense cache: (L, B, max_seq, H, dh). Paged pool:
        // (L, n_blocks, block_size, H, dh) + (B, n_logical) table, logical
        // capacity n_logical * block_size.
        let (max_seq, n_blocks, block_size) = if table_idx.is_some() {
            let n_blocks = cache_dims[1];
            let block_size = cache_dims[2];
            (n_logical * block_size, n_blocks, block_size)
        } else {
            (cache_dims[2], 0, 0)
        };
        Ok(Self {
            literals,
            token_idx: token_idx.ok_or_else(|| anyhow!("no token input"))?,
            pos_idx: pos_idx.ok_or_else(|| anyhow!("no pos input"))?,
            pos_scalar,
            table_idx,
            cache_k_idx: ck.ok_or_else(|| anyhow!("no cache_k input"))?,
            cache_v_idx: cv.ok_or_else(|| anyhow!("no cache_v input"))?,
            n_slots,
            max_seq,
            n_blocks,
            block_size,
            n_logical,
        })
    }

    /// Run one decode step: rebuild the token/pos (and block-table, when
    /// paged) literals, execute, keep the returned caches as literals (zero
    /// host round-trips), return the flat logits (n_slots * V).
    fn step(
        &mut self,
        exe: &Executable,
        tokens: &[i32],
        pos: &[i32],
        tables: Option<&[Vec<i32>]>,
    ) -> Result<Vec<f32>> {
        if tokens.len() != self.n_slots || pos.len() != self.n_slots {
            bail!(
                "{}: step arity {} / {}, artifact has {} slots",
                exe.label,
                tokens.len(),
                pos.len(),
                self.n_slots
            );
        }
        for (b, &p) in pos.iter().enumerate() {
            if (p as usize) >= self.max_seq {
                bail!("slot {b}: KV cache full ({} positions)", self.max_seq);
            }
        }
        match (self.table_idx, tables) {
            (Some(ti), Some(tables)) => {
                self.literals[ti] =
                    block_table_literal(tables, self.n_slots, self.n_logical, &exe.label)?;
            }
            (Some(_), None) => bail!("{}: paged artifact needs block tables", exe.label),
            (None, Some(_)) => bail!("{}: dense artifact got block tables", exe.label),
            (None, None) => {}
        }
        self.literals[self.token_idx] =
            xla::Literal::vec1(tokens).reshape(&[self.n_slots as i64])?;
        self.literals[self.pos_idx] = if self.pos_scalar {
            xla::Literal::scalar(pos[0])
        } else {
            xla::Literal::vec1(pos).reshape(&[self.n_slots as i64])?
        };
        let bufs = exe.run_literals_raw(&self.literals)?;
        let result = bufs[0][0].to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        // outputs: logits, cache_k, cache_v — keep caches as literals.
        let cache_v = parts.pop().ok_or_else(|| anyhow!("missing cache_v"))?;
        let cache_k = parts.pop().ok_or_else(|| anyhow!("missing cache_k"))?;
        let logits_lit = parts.pop().ok_or_else(|| anyhow!("missing logits"))?;
        self.literals[self.cache_k_idx] = cache_k;
        self.literals[self.cache_v_idx] = cache_v;
        Ok(logits_lit.to_vec::<f32>()?)
    }
}

// ---------------------------------------------------------------------------
// Shared PJRT prefill-artifact binding (prefill_*_b{N}_t{T})
// ---------------------------------------------------------------------------

/// Prepared input literals + index map for one batched prefill artifact.
/// The live KV cache stays owned by the [`DecodeBinding`]; each prefill
/// call borrows it in (as input literals) and hands the updated cache back,
/// so decode and prefill always see one coherent cache.
struct PrefillBinding {
    literals: Vec<xla::Literal>,
    tokens_idx: usize,
    pos_idx: usize,
    n_valid_idx: usize,
    /// Paged (`prefill_*_paged_b{N}_t{T}`) artifacts take a block table.
    table_idx: Option<usize>,
    cache_k_idx: usize,
    cache_v_idx: usize,
    n_slots: usize,
    t_chunk: usize,
    max_seq: usize,
    n_blocks: usize,
    block_size: usize,
    n_logical: usize,
}

/// Cheap stand-in literal used while a cache literal is moved between the
/// decode and prefill bindings (never executed).
fn placeholder_literal() -> xla::Literal {
    xla::Literal::scalar(0i32)
}

/// Flatten per-slot block tables into a `(n_slots, n_logical)` i32 literal
/// — shared by the decode and prefill bindings so their validation and
/// layout can never diverge.
fn block_table_literal(
    tables: &[Vec<i32>],
    n_slots: usize,
    n_logical: usize,
    label: &str,
) -> Result<xla::Literal> {
    if tables.len() != n_slots {
        bail!("{label}: {} block tables for {n_slots} slots", tables.len());
    }
    let mut flat = Vec::with_capacity(n_slots * n_logical);
    for (b, t) in tables.iter().enumerate() {
        if t.len() != n_logical {
            bail!(
                "{label}: slot {b} table has {} entries, artifact wants {n_logical}",
                t.len()
            );
        }
        flat.extend_from_slice(t);
    }
    Ok(xla::Literal::vec1(&flat).reshape(&[n_slots as i64, n_logical as i64])?)
}

/// Quant-variant token of a standard artifact label:
/// `"sq-2m/decode_nohad_b4"` -> `Some("nohad")`,
/// `"sq-2m/prefill_fp_b4_t16"` -> `Some("fp")`; `None` for custom labels.
fn label_variant(label: &str) -> Option<&str> {
    let name = label.rsplit('/').next().unwrap_or(label);
    let rest = name.strip_prefix("decode_").or_else(|| name.strip_prefix("prefill_"))?;
    rest.split('_').next()
}

impl PrefillBinding {
    fn new(exe: &Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let mut values = Vec::with_capacity(exe.spec.inputs.len());
        let (mut tok, mut pos, mut nv, mut table_idx, mut ck, mut cv) =
            (None, None, None, None, None, None);
        let (mut n_slots, mut t_chunk, mut n_logical) = (0usize, 0usize, 0usize);
        let mut cache_dims: Vec<usize> = Vec::new();
        for (i, (name, shape, _)) in exe.spec.inputs.iter().enumerate() {
            let v = match name.as_str() {
                "tokens" => {
                    tok = Some(i);
                    n_slots = shape.first().copied().unwrap_or(1);
                    t_chunk = shape.get(1).copied().unwrap_or(1);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "pos" => {
                    pos = Some(i);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "n_valid" => {
                    nv = Some(i);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "block_table" => {
                    table_idx = Some(i);
                    n_logical = shape.get(1).copied().unwrap_or(0);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "cache_k" => {
                    ck = Some(i);
                    cache_dims = shape.clone();
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "cache_v" => {
                    cv = Some(i);
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "qcfg" => Value::F32(
                    qcfg.ok_or_else(|| anyhow!("{}: needs qcfg", exe.label))?.tensor(),
                ),
                _ => Value::F32(weights.get(name)?.clone()),
            };
            values.push(v);
        }
        let mut literals = exe.prepare(&values)?;
        let cache_k_idx = ck.ok_or_else(|| anyhow!("{}: no cache_k input", exe.label))?;
        let cache_v_idx = cv.ok_or_else(|| anyhow!("{}: no cache_v input", exe.label))?;
        // The zero caches above only exist to satisfy prepare()'s shape
        // validation; the live cache is borrowed in from the decode binding
        // per call, so free them now instead of pinning a second cache.
        literals[cache_k_idx] = placeholder_literal();
        literals[cache_v_idx] = placeholder_literal();
        if cache_dims.len() < 3 {
            bail!("{}: malformed cache_k input", exe.label);
        }
        let (max_seq, n_blocks, block_size) = if table_idx.is_some() {
            (n_logical * cache_dims[2], cache_dims[1], cache_dims[2])
        } else {
            (cache_dims[2], 0, 0)
        };
        Ok(Self {
            literals,
            tokens_idx: tok.ok_or_else(|| anyhow!("{}: no tokens input", exe.label))?,
            pos_idx: pos.ok_or_else(|| anyhow!("{}: no pos input", exe.label))?,
            n_valid_idx: nv.ok_or_else(|| anyhow!("{}: no n_valid input", exe.label))?,
            table_idx,
            cache_k_idx,
            cache_v_idx,
            n_slots,
            t_chunk,
            max_seq,
            n_blocks,
            block_size,
            n_logical,
        })
    }

    /// Run one prefill call: borrow the live caches from `decode`, feed
    /// `tokens[b]` starting at `pos0[b]` for active slots, return the flat
    /// last-valid-position logits (n_slots * V) and hand the updated caches
    /// back to `decode`. (If execution fails the caches are lost — the
    /// engine is unusable, so PJRT errors stay **fatal** to the scheduler's
    /// error kernel; only classified [`ServeError::Transient`]/
    /// [`ServeError::Slot`] faults, whose contract is that no state
    /// advanced, are retried or warm-restarted by re-prefill through the
    /// recovery path.)
    fn step(
        &mut self,
        exe: &Executable,
        decode: &mut DecodeBinding,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
        tables: Option<&[Vec<i32>]>,
    ) -> Result<Vec<f32>> {
        if tokens.len() != self.n_slots || pos0.len() != self.n_slots {
            bail!(
                "{}: prefill arity {} / {}, artifact has {} slots",
                exe.label,
                tokens.len(),
                pos0.len(),
                self.n_slots
            );
        }
        match (self.table_idx, tables) {
            (Some(ti), Some(tables)) => {
                self.literals[ti] =
                    block_table_literal(tables, self.n_slots, self.n_logical, &exe.label)?;
            }
            (Some(_), None) => bail!("{}: paged artifact needs block tables", exe.label),
            (None, Some(_)) => bail!("{}: dense artifact got block tables", exe.label),
            (None, None) => {}
        }
        let mut flat_tokens = vec![0i32; self.n_slots * self.t_chunk];
        let mut pos_vec = vec![0i32; self.n_slots];
        let mut n_valid = vec![0i32; self.n_slots];
        for b in 0..self.n_slots {
            if !active[b] || tokens[b].is_empty() {
                continue;
            }
            if tokens[b].len() > self.t_chunk {
                bail!(
                    "{}: slot {b} fed {} tokens, chunk is {}",
                    exe.label,
                    tokens[b].len(),
                    self.t_chunk
                );
            }
            let end = pos0[b] as usize + tokens[b].len();
            if end > self.max_seq {
                bail!("slot {b}: prefill past KV capacity ({} positions)", self.max_seq);
            }
            flat_tokens[b * self.t_chunk..b * self.t_chunk + tokens[b].len()]
                .copy_from_slice(&tokens[b]);
            pos_vec[b] = pos0[b];
            n_valid[b] = tokens[b].len() as i32;
        }
        self.literals[self.tokens_idx] = xla::Literal::vec1(&flat_tokens)
            .reshape(&[self.n_slots as i64, self.t_chunk as i64])?;
        self.literals[self.pos_idx] =
            xla::Literal::vec1(&pos_vec).reshape(&[self.n_slots as i64])?;
        self.literals[self.n_valid_idx] =
            xla::Literal::vec1(&n_valid).reshape(&[self.n_slots as i64])?;
        // Move the live caches in from the decode binding for this call.
        self.literals[self.cache_k_idx] =
            std::mem::replace(&mut decode.literals[decode.cache_k_idx], placeholder_literal());
        self.literals[self.cache_v_idx] =
            std::mem::replace(&mut decode.literals[decode.cache_v_idx], placeholder_literal());
        let bufs = exe.run_literals_raw(&self.literals)?;
        // Drop the consumed pre-call cache copies immediately — otherwise
        // this binding would pin a second cache-sized literal pair for the
        // engine's whole lifetime.
        self.literals[self.cache_k_idx] = placeholder_literal();
        self.literals[self.cache_v_idx] = placeholder_literal();
        let result = bufs[0][0].to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        let cache_v = parts.pop().ok_or_else(|| anyhow!("missing cache_v"))?;
        let cache_k = parts.pop().ok_or_else(|| anyhow!("missing cache_k"))?;
        let logits_lit = parts.pop().ok_or_else(|| anyhow!("missing logits"))?;
        decode.literals[decode.cache_k_idx] = cache_k;
        decode.literals[decode.cache_v_idx] = cache_v;
        Ok(logits_lit.to_vec::<f32>()?)
    }
}

// ---------------------------------------------------------------------------
// PJRT-backed engine
// ---------------------------------------------------------------------------

/// The production engine: one compiled decode artifact, weight + cache
/// literals prepared once, token/pos literals rebuilt per step. Optionally
/// carries a batched prefill artifact ([`PjrtEngine::with_prefill`]) that
/// consumes `T` prompt tokens per call; without one, `prefill` falls back
/// to the chunked decode loop.
pub struct PjrtEngine {
    exe: Executable,
    bind: DecodeBinding,
    prefill_exe: Option<Executable>,
    prefill_bind: Option<PrefillBinding>,
    /// KV storage width the bound qcfg asks the graphs for (16 = fp).
    kv_bits: f32,
    pub step_times: Samples,
    pub prefill_times: Samples,
}

impl PjrtEngine {
    /// Build from a compiled decode artifact (takes ownership so callers
    /// can move the engine into schedulers/threads without self-reference).
    pub fn new(exe: Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let bind = DecodeBinding::new(&exe, weights, qcfg)?;
        Ok(Self {
            exe,
            bind,
            prefill_exe: None,
            prefill_bind: None,
            kv_bits: qcfg.map(|q| q.0[1]).unwrap_or(16.0),
            step_times: Samples::new(),
            prefill_times: Samples::new(),
        })
    }

    /// Attach a compiled `prefill_*_b{N}_t{T}` artifact. Its slot count and
    /// cache capacity must match the decode artifact's.
    pub fn with_prefill(
        mut self,
        exe: Executable,
        weights: &Weights,
        qcfg: Option<QcfgVec>,
    ) -> Result<Self> {
        let bind = PrefillBinding::new(&exe, weights, qcfg)?;
        if bind.n_slots != self.bind.n_slots || bind.max_seq != self.bind.max_seq {
            bail!(
                "{}: prefill artifact is {} slots x {} positions, decode is {} x {}",
                exe.label,
                bind.n_slots,
                bind.max_seq,
                self.bind.n_slots,
                self.bind.max_seq
            );
        }
        // Paged-ness and page layout must agree, or the two bindings would
        // interpret the shared cache literals differently.
        if bind.table_idx.is_some() != self.bind.table_idx.is_some()
            || bind.n_blocks != self.bind.n_blocks
            || bind.block_size != self.bind.block_size
        {
            bail!(
                "{}: prefill KV layout ({} pages x {}) does not match decode ({} x {})",
                exe.label,
                bind.n_blocks,
                bind.block_size,
                self.bind.n_blocks,
                self.bind.block_size
            );
        }
        if bind.t_chunk < 2 {
            bail!("{}: prefill chunk {} gains nothing over decode", exe.label, bind.t_chunk);
        }
        // A prefill graph of a different quant variant would silently write
        // differently-quantized KV entries into the shared cache.
        if let (Some(dv), Some(pv)) =
            (label_variant(&self.exe.label), label_variant(&exe.label))
        {
            if dv != pv {
                bail!(
                    "{}: prefill variant {pv:?} does not match decode variant {dv:?} ({})",
                    exe.label,
                    self.exe.label
                );
            }
        }
        self.prefill_exe = Some(exe);
        self.prefill_bind = Some(bind);
        Ok(self)
    }

    pub fn label(&self) -> &str {
        &self.exe.label
    }

    pub fn ms_per_step(&self) -> f64 {
        self.step_times.mean_us() / 1e3
    }
}

impl DecodeEngine for PjrtEngine {
    fn slots(&self) -> usize {
        self.bind.n_slots
    }

    fn max_seq(&self) -> usize {
        self.bind.max_seq
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32], _active: &[bool]) -> Result<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let flat = self.bind.step(&self.exe, tokens, pos, None)?;
        self.step_times.push(t0.elapsed().as_secs_f64() * 1e6);
        let vocab = flat.len() / self.bind.n_slots.max(1);
        Ok(flat.chunks(vocab).map(|c| c.to_vec()).collect())
    }

    fn prefill_chunk(&self) -> usize {
        self.prefill_bind.as_ref().map(|p| p.t_chunk).unwrap_or(1)
    }

    fn prefill(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<f32>>> {
        if self.prefill_bind.is_none() {
            return prefill_by_steps(self, tokens, pos0, active);
        }
        if active.len() != self.bind.n_slots {
            bail!("prefill arity mismatch ({} slots)", self.bind.n_slots);
        }
        let t0 = Instant::now();
        let pb = self.prefill_bind.as_mut().expect("checked above");
        let pexe = self.prefill_exe.as_ref().expect("set with binding");
        let flat = pb.step(pexe, &mut self.bind, tokens, pos0, active, None)?;
        self.prefill_times.push(t0.elapsed().as_secs_f64() * 1e6);
        let vocab = flat.len() / pb.n_slots.max(1);
        let mut out = Vec::with_capacity(pb.n_slots);
        for (b, lane) in flat.chunks(vocab).enumerate() {
            if active[b] && !tokens[b].is_empty() {
                out.push(lane.to_vec());
            } else {
                out.push(Vec::new());
            }
        }
        Ok(out)
    }

    fn reset_slot(&mut self, _slot: usize) {
        // Nothing to do: attention masking (`idx <= pos`) makes a previous
        // occupant's stale cache entries unreachable once the slot restarts
        // at pos = 0.
    }

    fn kv_block_size(&self) -> Option<usize> {
        self.bind.table_idx.map(|_| self.bind.block_size)
    }

    fn kv_blocks(&self) -> usize {
        self.bind.n_blocks
    }

    fn kv_bits(&self) -> f32 {
        self.kv_bits
    }

    fn step_paged(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        _active: &[bool],
        tables: &[Vec<i32>],
    ) -> Result<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let flat = self.bind.step(&self.exe, tokens, pos, Some(tables))?;
        self.step_times.push(t0.elapsed().as_secs_f64() * 1e6);
        let vocab = flat.len() / self.bind.n_slots.max(1);
        Ok(flat.chunks(vocab).map(|c| c.to_vec()).collect())
    }

    fn prefill_paged(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
        tables: &[Vec<i32>],
    ) -> Result<Vec<Vec<f32>>> {
        if self.prefill_bind.is_none() {
            return prefill_paged_by_steps(self, tokens, pos0, active, tables);
        }
        if active.len() != self.bind.n_slots {
            bail!("prefill arity mismatch ({} slots)", self.bind.n_slots);
        }
        let t0 = Instant::now();
        let pb = self.prefill_bind.as_mut().expect("checked above");
        let pexe = self.prefill_exe.as_ref().expect("set with binding");
        let flat = pb.step(pexe, &mut self.bind, tokens, pos0, active, Some(tables))?;
        self.prefill_times.push(t0.elapsed().as_secs_f64() * 1e6);
        let vocab = flat.len() / pb.n_slots.max(1);
        let mut out = Vec::with_capacity(pb.n_slots);
        for (b, lane) in flat.chunks(vocab).enumerate() {
            if active[b] && !tokens[b].is_empty() {
                out.push(lane.to_vec());
            } else {
                out.push(Vec::new());
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Deterministic mock engine (tests + artifact-free benches)
// ---------------------------------------------------------------------------

/// A deterministic fake model. Logits for a slot are a pure function of the
/// slot's token *history* (not of the slot index, the batch composition, or
/// the wall clock), so the same request produces the same generation at any
/// batch size — exactly the property continuous-batching tests need.
///
/// It also asserts the scheduler's contract: a step's `pos[b]` must equal
/// the number of tokens already fed into slot `b`, and reused slots must be
/// reset. Violations are reported as errors instead of silent corruption.
///
/// The history hash that seeds the logits is maintained *incrementally*
/// (one fold per appended token) instead of rehashing the whole history per
/// step — the old path made every decode step O(len), O(len^2) per request.
/// [`MockEngine::logits_for`] keeps the from-scratch computation as the
/// regression reference.
///
/// In paged mode ([`MockEngine::with_block_pool`]) tokens are additionally
/// stored in *physical* `block_size`-token pages addressed through the
/// step's block tables, and every call asserts the copy-on-write sharing
/// contract: each slot's table-reconstructed history must match its true
/// history (so any physical page shared by several slots necessarily holds
/// identical token content for all of them), writes are exclusive — no two
/// slots may write one page in a call, and no write may land in a page
/// another slot maps inside its readable prefix. Table corruption (holes,
/// stale mappings, clobbered shared pages) surfaces as a loud error, not a
/// simulation artifact. [`MockEngine::adopt_prefix`] seeds a slot's
/// history from the shared pages its table maps, mirroring what the real
/// graphs see by gathering KV through an aliased table.
///
/// With [`MockEngine::with_kv_bits`] below 16, every cached position also
/// carries a synthetic KV row through a *real* symmetric
/// quantize→pack→unpack→dequantize round trip (the `crate::quant` codec the
/// serving accounting is based on); paged pages store the round-tripped
/// payload, and each slot's accumulated row error deterministically
/// perturbs its logits ([`MockEngine::logits_for_kv`] is the from-scratch
/// reference). The perturbation is scaled so int8 storage provably never
/// flips a greedy argmax while int4 does after a few dozen positions —
/// giving schedulers, benches and the sim oracle an exactly reproducible
/// stand-in for quantized-KV quality drift.
pub struct MockEngine {
    n_slots: usize,
    max_seq: usize,
    vocab: usize,
    history: Vec<Vec<i32>>,
    /// Incremental history hash per slot (`HASH_BASIS` folded once per
    /// appended token).
    hash: Vec<u64>,
    chunk: usize,
    /// Paged mode: tokens per physical page (None = dense).
    block_size: Option<usize>,
    /// Paged mode: physical page storage — each written position holds its
    /// token plus the *stored* (quantize→dequantize round-tripped at
    /// `kv_bits`) synthetic KV row, mirroring what the real quantized paged
    /// graphs keep resident.
    blocks: Vec<Vec<PageEntry>>,
    /// KV storage width in bits (16 = full precision, no drift).
    kv_bits: f32,
    /// Per-slot accumulated L1 quantization error of the slot's cached KV
    /// rows — the state the deterministic drift model perturbs logits with.
    kv_err: Vec<f32>,
    /// Total decode steps executed (for batching-efficiency assertions).
    pub steps: usize,
    /// Total batched prefill calls executed (a prompt of `len` tokens must
    /// cost exactly `ceil(len/chunk)` of these — the TTFT acceptance check).
    pub prefill_calls: usize,
    /// Total prompt tokens consumed across all prefill calls.
    pub prefill_tokens_fed: usize,
    /// Largest single prefill call, summed over slots — the step
    /// composer's budget-compliance observable: with `--step-budget B` no
    /// prefill call may carry more than `max(B - decode_lanes, guard)`
    /// prompt tokens, and tests assert it against this counter.
    pub max_prefill_call_tokens: usize,
    /// Total speculative verify calls executed. Deliberately **not** folded
    /// into `prefill_calls`: verify windows flow through the same ragged
    /// multi-token graphs, but the budget-compliance observables above are
    /// about *prompt* prefill, and conflating the two would let a
    /// speculative run silently satisfy (or break) a prefill-budget assert.
    pub verify_calls: usize,
    /// Draft tokens checked across all verify calls — each lane of a verify
    /// call carries `1 + drafts` tokens, and this counts the `drafts` part
    /// (the `1` is the token a plain decode step would have fed anyway).
    pub draft_tokens_verified: usize,
}

/// FNV-1a offset basis / prime: the history hash the mock's logits seed on.
const HASH_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
const HASH_PRIME: u64 = 0x0000_0100_0000_01b3;

fn hash_fold(h: u64, token: i32) -> u64 {
    (h ^ token as u64).wrapping_mul(HASH_PRIME)
}

/// Synthetic KV row width per cached token — matches sq-2m's per-layer
/// `n_heads x d_head` (4 x 32) so the mock pool's measured bytes line up
/// with [`crate::serve::blocks::kv_memory_bytes`] at `n_layers = 1`.
pub const MOCK_KV_DIM: usize = 128;
/// Quantization group size within a row (one group per head: `d_head`).
pub const MOCK_KV_GROUP: usize = 32;
/// Drift coefficient: each logit is perturbed by `DRIFT x kv_err x u`,
/// `u ∈ [-1, 1)`. Sized so int8 KV (per-token row error ≈ 0.25, so
/// `kv_err <= 32` over a full 128-position history) moves any logit by
/// < 1.3 — strictly inside the mock's guaranteed > 4 greedy gap, making
/// int8 greedy completions *provably* byte-identical to fp — while int4
/// (per-token error ≈ 4.5) crosses the gap within a few dozen tokens.
const MOCK_KV_DRIFT: f32 = 0.04;

/// One written position in a mock physical page: the token plus the KV
/// payload actually stored at `kv_bits`.
#[derive(Clone, Debug, PartialEq)]
struct PageEntry {
    token: i32,
    kv: KvPayload,
}

/// What the mock pool keeps resident for one cached position.
#[derive(Clone, Debug, PartialEq)]
enum KvPayload {
    /// `kv_bits >= 16`: the row is stored exactly (f16 elements in the
    /// real pool — 2 bytes each for accounting; regenerated on read since
    /// the row is a pure function of (token, pos)).
    Exact,
    /// Quantized storage: symmetric codes packed to `bits` (offset-binary
    /// nibbles at 4, one byte per code at 8) + one f16 scale per
    /// [`MOCK_KV_GROUP`]-element group.
    Quant { bits: u8, packed: Vec<u8>, scales: Vec<f32> },
}

impl KvPayload {
    /// The row as the gather path sees it: exact for fp, decode(pack) for
    /// quantized storage.
    fn dequantize(&self, token: i32, pos: usize) -> Vec<f32> {
        match self {
            KvPayload::Exact => MockEngine::mock_kv_row(token, pos),
            KvPayload::Quant { bits, packed, scales } => {
                let codes = if *bits == 4 {
                    crate::quant::unpack_int4_symmetric(packed, MOCK_KV_DIM)
                } else {
                    packed.iter().map(|&b| b as i8 as i32).collect()
                };
                let mut out = Vec::with_capacity(MOCK_KV_DIM);
                for (g, grp) in codes.chunks(MOCK_KV_GROUP).enumerate() {
                    out.extend(crate::quant::dequantize_codes(grp, scales[g], 0.0));
                }
                out
            }
        }
    }

    /// Bytes this position occupies in the pool (f16 scales/elements).
    fn resident_bytes(&self) -> usize {
        match self {
            KvPayload::Exact => MOCK_KV_DIM * 2,
            KvPayload::Quant { packed, scales, .. } => packed.len() + scales.len() * 2,
        }
    }
}

impl MockEngine {
    pub fn new(slots: usize, max_seq: usize, vocab: usize) -> Self {
        Self {
            n_slots: slots,
            max_seq,
            vocab,
            history: vec![Vec::new(); slots],
            hash: vec![HASH_BASIS; slots],
            chunk: 1,
            block_size: None,
            blocks: Vec::new(),
            kv_bits: 16.0,
            kv_err: vec![0.0; slots],
            steps: 0,
            prefill_calls: 0,
            prefill_tokens_fed: 0,
            max_prefill_call_tokens: 0,
            verify_calls: 0,
            draft_tokens_verified: 0,
        }
    }

    /// Account one prefill call's total fed tokens (budget observables).
    fn count_prefill_tokens(&mut self, tokens: &[Vec<i32>], active: &[bool]) {
        let fed: usize =
            (0..self.n_slots).filter(|&b| active[b]).map(|b| tokens[b].len()).sum();
        self.prefill_tokens_fed += fed;
        self.max_prefill_call_tokens = self.max_prefill_call_tokens.max(fed);
    }

    /// Pretend to be an engine with a `T`-token prefill graph (chunk 1 =
    /// no batched prefill, the default).
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Self {
        self.chunk = chunk.max(1);
        self
    }

    /// Paged mode: a pool of `n_blocks` physical pages of `block_size`
    /// tokens, driven through `step_paged` / `prefill_paged`.
    pub fn with_block_pool(mut self, n_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        self.block_size = Some(block_size);
        self.blocks = vec![Vec::new(); n_blocks];
        self
    }

    /// Store KV at `bits` per element (4, 8 or 16). Below 16 every cached
    /// position's synthetic KV row goes through a real symmetric
    /// quantize→pack→unpack→dequantize round trip; the accumulated row
    /// error deterministically perturbs the slot's logits, so quantization
    /// quality is *observable* (and exactly reproducible) without a model.
    pub fn with_kv_bits(mut self, bits: f32) -> Self {
        assert!(
            bits == 4.0 || bits == 8.0 || bits == 16.0,
            "mock engine: kv_bits must be 4, 8 or 16 (got {bits})"
        );
        self.kv_bits = bits;
        self
    }

    /// The synthetic KV row cached for (token, pos): MOCK_KV_DIM uniforms
    /// in [-1, 1), a pure function of its arguments — so dense and paged
    /// engines, and the sim oracle, all agree without shared state.
    fn mock_kv_row(token: i32, pos: usize) -> Vec<f32> {
        let seed = hash_fold(hash_fold(HASH_BASIS, token), pos as i32);
        let mut rng = Prng::new(seed);
        (0..MOCK_KV_DIM).map(|_| rng.uniform() * 2.0 - 1.0).collect()
    }

    /// Encode one row for storage at `kv_bits` (symmetric grid, per-group
    /// scales; int4 through the offset-binary nibble codec).
    fn encode_kv(row: &[f32], kv_bits: f32) -> KvPayload {
        if kv_bits >= 16.0 {
            return KvPayload::Exact;
        }
        let mut codes = Vec::with_capacity(MOCK_KV_DIM);
        let mut scales = Vec::with_capacity(MOCK_KV_DIM / MOCK_KV_GROUP);
        for grp in row.chunks(MOCK_KV_GROUP) {
            let (c, scale, _zero) = crate::quant::quantize_group_codes(grp, kv_bits, true);
            codes.extend(c);
            scales.push(scale);
        }
        let packed = if kv_bits == 4.0 {
            crate::quant::pack_int4_symmetric(&codes)
        } else {
            codes.iter().map(|&c| c as i8 as u8).collect()
        };
        KvPayload::Quant { bits: kv_bits as u8, packed, scales }
    }

    /// L1 error the storage round trip adds to (token, pos)'s row at
    /// `kv_bits` — 0 at full precision.
    fn kv_round_trip_err(token: i32, pos: usize, kv_bits: f32) -> f32 {
        if kv_bits >= 16.0 {
            return 0.0;
        }
        let row = Self::mock_kv_row(token, pos);
        let deq = Self::encode_kv(&row, kv_bits).dequantize(token, pos);
        row.iter().zip(&deq).map(|(x, y)| (x - y).abs()).sum()
    }

    /// Deterministic logit perturbation from accumulated KV storage error:
    /// `logits[i] += DRIFT x kv_err x u_i`, `u_i` seeded by (history hash,
    /// kv_bits). No-op at 16 bits, so the fp path stays byte-identical to
    /// an engine built without `with_kv_bits`.
    fn apply_kv_drift(logits: &mut [f32], hash: u64, kv_bits: f32, kv_err: f32) {
        if kv_bits >= 16.0 {
            return;
        }
        let mut rng = Prng::new(hash ^ ((kv_bits.to_bits() as u64) << 17));
        for l in logits.iter_mut() {
            *l += MOCK_KV_DRIFT * kv_err * (rng.uniform() * 2.0 - 1.0);
        }
    }

    /// Measured resident bytes of the physical pool: what the stored page
    /// payloads actually occupy (one KV "side" — the real pool holds K and
    /// V, so compare `2x` this against
    /// [`crate::serve::blocks::kv_memory_bytes`]).
    pub fn resident_kv_bytes(&self) -> usize {
        self.blocks.iter().flatten().map(|e| e.kv.resident_bytes()).sum()
    }

    /// The engine's slot-local logits: the history-hash base plus the KV
    /// drift term for this slot's accumulated storage error.
    fn slot_logits(&self, b: usize, last: i32) -> Vec<f32> {
        let mut logits =
            Self::logits_from(self.hash[b], self.history[b].len(), last, self.vocab);
        Self::apply_kv_drift(&mut logits, self.hash[b], self.kv_bits, self.kv_err[b]);
        logits
    }

    /// Deterministic logits from the incrementally maintained state: a
    /// pseudo-random base (hash-seeded, so temperature sampling has
    /// texture) plus a strong peak on the "predicted" next token.
    fn logits_from(hash: u64, len: usize, last: i32, vocab: usize) -> Vec<f32> {
        let mut rng = Prng::new(hash);
        let mut logits: Vec<f32> = (0..vocab).map(|_| rng.uniform() * 4.0).collect();
        let last = if len == 0 { 0 } else { last as usize };
        let peak = (last * 31 + len * 7 + 13) % vocab;
        logits[peak] += 8.0;
        logits
    }

    /// From-scratch reference of the logits computation (rehashes the whole
    /// history). Tests assert `logits_from` over the incremental hash is
    /// bit-identical to this.
    pub fn logits_for(history: &[i32], vocab: usize) -> Vec<f32> {
        let h = history.iter().fold(HASH_BASIS, |h, &t| hash_fold(h, t));
        Self::logits_from(h, history.len(), *history.last().unwrap_or(&0), vocab)
    }

    /// From-scratch reference of the *quantized-KV* logits: [`logits_for`]
    /// plus the drift term over the whole history's storage error at
    /// `kv_bits`. Bit-identical to `logits_for` at 16 bits; the sim oracle
    /// predicts a `with_kv_bits` engine with this.
    pub fn logits_for_kv(history: &[i32], vocab: usize, kv_bits: f32) -> Vec<f32> {
        let h = history.iter().fold(HASH_BASIS, |h, &t| hash_fold(h, t));
        let mut logits =
            Self::logits_from(h, history.len(), *history.last().unwrap_or(&0), vocab);
        let err: f32 = history
            .iter()
            .enumerate()
            .map(|(pos, &t)| Self::kv_round_trip_err(t, pos, kv_bits))
            .sum();
        Self::apply_kv_drift(&mut logits, h, kv_bits, err);
        logits
    }

    /// Append one token to slot `b`'s true history + incremental hash, and
    /// accrue the storage error its cached KV row picks up at `kv_bits`.
    fn push_token(&mut self, b: usize, token: i32) {
        let pos = self.history[b].len();
        self.history[b].push(token);
        self.hash[b] = hash_fold(self.hash[b], token);
        self.kv_err[b] += Self::kv_round_trip_err(token, pos, self.kv_bits);
    }

    /// Write one token into the physical page the table maps `pos` to,
    /// asserting sequential in-page order (a page acquired fresh is written
    /// from offset 0, which resets whatever a previous owner left there).
    fn paged_write(&mut self, b: usize, pos: usize, token: i32, table: &[i32]) -> Result<()> {
        let bs = self.block_size.expect("paged mode");
        let j = pos / bs;
        let off = pos % bs;
        let phys = table.get(j).copied().unwrap_or(-1);
        if phys < 0 || phys as usize >= self.blocks.len() {
            bail!(
                "mock engine: slot {b} write at pos {pos} through unmapped page \
                 (table[{j}] = {phys}, pool has {} pages)",
                self.blocks.len()
            );
        }
        let kv = Self::encode_kv(&Self::mock_kv_row(token, pos), self.kv_bits);
        let page = &mut self.blocks[phys as usize];
        if off == 0 {
            page.clear();
        }
        if page.len() != off {
            bail!(
                "mock engine: slot {b} writes page {phys} at offset {off} but the page \
                 holds {} tokens (page aliased or positions out of order)",
                page.len()
            );
        }
        page.push(PageEntry { token, kv });
        Ok(())
    }

    /// Rebuild slot `b`'s history through its block table and require it to
    /// match the true history — the paged-path integrity check.
    fn check_paged_view(&self, b: usize, table: &[i32]) -> Result<()> {
        let bs = self.block_size.expect("paged mode");
        let hist = &self.history[b];
        let mut consumed = 0usize;
        let mut j = 0usize;
        while consumed < hist.len() {
            let take = bs.min(hist.len() - consumed);
            let phys = table.get(j).copied().unwrap_or(-1);
            let page = (phys >= 0)
                .then(|| self.blocks.get(phys as usize))
                .flatten()
                .ok_or_else(|| {
                    anyhow!("mock engine: slot {b} history spans unmapped page table[{j}]")
                })?;
            if page.len() != take
                || page.iter().map(|e| e.token).ne(hist[consumed..consumed + take].iter().copied())
            {
                bail!(
                    "mock engine: slot {b} page {phys} diverges from history at logical \
                     page {j} (paged KV corruption)"
                );
            }
            consumed += take;
            j += 1;
        }
        Ok(())
    }

    /// Shared physical pages are strictly read-only: no page written in
    /// this call may be written by two slots at once (write-write), and no
    /// written page may be mapped inside another slot's already-written
    /// readable prefix (write-read — clobbering a prefix another request
    /// is still attending over). `writes[b]` is slot `b`'s write range
    /// `(start_pos, n_tokens)` for this call (`n == 0`: no write).
    fn check_exclusive_writes(&self, writes: &[(usize, usize)], tables: &[Vec<i32>]) -> Result<()> {
        let bs = self.block_size.expect("paged mode");
        let mut written: Vec<(i32, usize)> = Vec::new();
        for b in 0..self.n_slots {
            let (start, n) = writes[b];
            if n == 0 {
                continue;
            }
            for j in (start / bs)..=((start + n - 1) / bs) {
                let e = tables.get(b).and_then(|t| t.get(j)).copied().unwrap_or(-1);
                // Unmapped / sentinel entries are paged_write's problem.
                if e >= 0 && (e as usize) < self.blocks.len() {
                    written.push((e, b));
                }
            }
        }
        for (i, &(p, b)) in written.iter().enumerate() {
            for &(p2, b2) in &written[i + 1..] {
                if p == p2 && b != b2 {
                    bail!(
                        "mock engine: slots {b} and {b2} both write physical page {p} \
                         (copy-on-write violated)"
                    );
                }
            }
            for c in 0..self.n_slots {
                if c == b {
                    continue;
                }
                let read_pages = self.history[c].len().div_ceil(bs);
                if tables[c].iter().take(read_pages).any(|&e| e == p) {
                    bail!(
                        "mock engine: slot {b} writes physical page {p}, which slot {c} \
                         maps read-only in its prefix (shared page clobbered)"
                    );
                }
            }
        }
        Ok(())
    }

    /// After every paged call: each slot holding tokens must be able to
    /// reconstruct its exact history through its table — so any two slots
    /// sharing a physical page necessarily agree on its content, which is
    /// the prefix-sharing correctness condition.
    fn check_all_views(&self, tables: &[Vec<i32>]) -> Result<()> {
        for b in 0..self.n_slots {
            if !self.history[b].is_empty() {
                self.check_paged_view(b, &tables[b])?;
            }
        }
        Ok(())
    }
}

impl DecodeEngine for MockEngine {
    fn slots(&self) -> usize {
        self.n_slots
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32], active: &[bool]) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != self.n_slots || pos.len() != self.n_slots || active.len() != self.n_slots
        {
            bail!("mock engine: step arity mismatch ({} slots)", self.n_slots);
        }
        if self.block_size.is_some() {
            bail!("mock engine: paged engine stepped without block tables (use step_paged)");
        }
        self.steps += 1;
        let mut out = Vec::with_capacity(self.n_slots);
        for b in 0..self.n_slots {
            if !active[b] {
                out.push(Vec::new());
                continue;
            }
            if pos[b] as usize != self.history[b].len() {
                bail!(
                    "mock engine: slot {b} stepped at pos {} but holds {} tokens \
                     (scheduler position tracking broken, or slot reused without reset)",
                    pos[b],
                    self.history[b].len()
                );
            }
            if self.history[b].len() >= self.max_seq {
                bail!("mock engine: slot {b} cache full ({} positions)", self.max_seq);
            }
            self.push_token(b, tokens[b]);
            out.push(self.slot_logits(b, tokens[b]));
        }
        Ok(out)
    }

    fn prefill_chunk(&self) -> usize {
        self.chunk
    }

    fn prefill(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != self.n_slots || pos0.len() != self.n_slots || active.len() != self.n_slots
        {
            bail!("mock engine: prefill arity mismatch ({} slots)", self.n_slots);
        }
        if self.block_size.is_some() {
            bail!("mock engine: paged engine prefilled without block tables");
        }
        self.prefill_calls += 1;
        self.count_prefill_tokens(tokens, active);
        let mut out = Vec::with_capacity(self.n_slots);
        for b in 0..self.n_slots {
            if !active[b] || tokens[b].is_empty() {
                out.push(Vec::new());
                continue;
            }
            if tokens[b].len() > self.chunk {
                bail!(
                    "mock engine: slot {b} fed {} prefill tokens, chunk is {}",
                    tokens[b].len(),
                    self.chunk
                );
            }
            if pos0[b] as usize != self.history[b].len() {
                bail!(
                    "mock engine: slot {b} prefilled at pos {} but holds {} tokens \
                     (scheduler position tracking broken, or slot reused without reset)",
                    pos0[b],
                    self.history[b].len()
                );
            }
            if self.history[b].len() + tokens[b].len() > self.max_seq {
                bail!("mock engine: slot {b} prefill past cache ({} positions)", self.max_seq);
            }
            for t in tokens[b].clone() {
                self.push_token(b, t);
            }
            let last = *self.history[b].last().expect("non-empty");
            out.push(self.slot_logits(b, last));
        }
        Ok(out)
    }

    fn reset_slot(&mut self, slot: usize) {
        self.history[slot].clear();
        self.hash[slot] = HASH_BASIS;
        self.kv_err[slot] = 0.0;
    }

    fn kv_block_size(&self) -> Option<usize> {
        self.block_size
    }

    fn kv_blocks(&self) -> usize {
        self.blocks.len()
    }

    fn kv_bits(&self) -> f32 {
        self.kv_bits
    }

    fn step_paged(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[bool],
        tables: &[Vec<i32>],
    ) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != self.n_slots
            || pos.len() != self.n_slots
            || active.len() != self.n_slots
            || tables.len() != self.n_slots
        {
            bail!("mock engine: paged step arity mismatch ({} slots)", self.n_slots);
        }
        if self.block_size.is_none() {
            bail!("mock engine: dense engine got block tables (build with with_block_pool)");
        }
        self.steps += 1;
        let writes: Vec<(usize, usize)> = (0..self.n_slots)
            .map(|b| if active[b] { (pos[b] as usize, 1) } else { (0, 0) })
            .collect();
        self.check_exclusive_writes(&writes, tables)?;
        let mut out = Vec::with_capacity(self.n_slots);
        for b in 0..self.n_slots {
            if !active[b] {
                out.push(Vec::new());
                continue;
            }
            if pos[b] as usize != self.history[b].len() {
                bail!(
                    "mock engine: slot {b} stepped at pos {} but holds {} tokens \
                     (scheduler position tracking broken, or slot reused without reset)",
                    pos[b],
                    self.history[b].len()
                );
            }
            if self.history[b].len() >= self.max_seq {
                bail!("mock engine: slot {b} cache full ({} positions)", self.max_seq);
            }
            self.paged_write(b, pos[b] as usize, tokens[b], &tables[b])?;
            self.push_token(b, tokens[b]);
            out.push(self.slot_logits(b, tokens[b]));
        }
        // Every slot (the ones idling through this call included) must
        // still see its exact history through its table: shared pages hold
        // identical content for all their readers, or this fails loudly.
        self.check_all_views(tables)?;
        Ok(out)
    }

    fn prefill_paged(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
        tables: &[Vec<i32>],
    ) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != self.n_slots
            || pos0.len() != self.n_slots
            || active.len() != self.n_slots
            || tables.len() != self.n_slots
        {
            bail!("mock engine: paged prefill arity mismatch ({} slots)", self.n_slots);
        }
        if self.block_size.is_none() {
            bail!("mock engine: dense engine got block tables (build with with_block_pool)");
        }
        self.prefill_calls += 1;
        self.count_prefill_tokens(tokens, active);
        let writes: Vec<(usize, usize)> = (0..self.n_slots)
            .map(|b| if active[b] { (pos0[b] as usize, tokens[b].len()) } else { (0, 0) })
            .collect();
        self.check_exclusive_writes(&writes, tables)?;
        let mut out = Vec::with_capacity(self.n_slots);
        for b in 0..self.n_slots {
            if !active[b] || tokens[b].is_empty() {
                out.push(Vec::new());
                continue;
            }
            if tokens[b].len() > self.chunk {
                bail!(
                    "mock engine: slot {b} fed {} prefill tokens, chunk is {}",
                    tokens[b].len(),
                    self.chunk
                );
            }
            if pos0[b] as usize != self.history[b].len() {
                bail!(
                    "mock engine: slot {b} prefilled at pos {} but holds {} tokens \
                     (scheduler position tracking broken, or slot reused without reset)",
                    pos0[b],
                    self.history[b].len()
                );
            }
            if self.history[b].len() + tokens[b].len() > self.max_seq {
                bail!("mock engine: slot {b} prefill past cache ({} positions)", self.max_seq);
            }
            for t in 0..tokens[b].len() {
                let tok = tokens[b][t];
                self.paged_write(b, pos0[b] as usize + t, tok, &tables[b])?;
                self.push_token(b, tok);
            }
            let last = *self.history[b].last().expect("non-empty");
            out.push(self.slot_logits(b, last));
        }
        self.check_all_views(tables)?;
        Ok(out)
    }

    fn adopt_prefix(&mut self, slot: usize, table: &[i32], cached: usize) -> Result<()> {
        let Some(bs) = self.block_size else {
            bail!("mock engine: adopt_prefix on a dense engine");
        };
        // Rebuild the slot's history from the shared physical pages its
        // table maps — exactly what the real graphs "see" by gathering KV
        // through the table — so position and content assertions hold from
        // the first post-admission step.
        let mut toks = Vec::with_capacity(cached);
        for pos in 0..cached {
            let j = pos / bs;
            let phys = table.get(j).copied().unwrap_or(-1);
            let page = (phys >= 0)
                .then(|| self.blocks.get(phys as usize))
                .flatten()
                .ok_or_else(|| {
                    anyhow!("mock engine: slot {slot} adopts unmapped page table[{j}] = {phys}")
                })?;
            let entry = page.get(pos % bs).ok_or_else(|| {
                anyhow!(
                    "mock engine: slot {slot} adopts page {phys} holding {} tokens at \
                     in-page offset {} (shared page not full)",
                    page.len(),
                    pos % bs
                )
            })?;
            // The adopted KV must be what this engine would have stored for
            // (token, pos) at its own kv_bits: a donor page written at a
            // different width (or corrupted payload) would silently change
            // the adopter's attention inputs in the real graphs.
            let canon = Self::encode_kv(&Self::mock_kv_row(entry.token, pos), self.kv_bits);
            if entry.kv != canon {
                bail!(
                    "mock engine: slot {slot} adopts page {phys} whose stored KV at \
                     in-page offset {} does not match a {}-bit round trip of its token \
                     (mixed-width or corrupted shared page)",
                    pos % bs,
                    self.kv_bits
                );
            }
            toks.push(entry.token);
        }
        self.history[slot].clear();
        self.hash[slot] = HASH_BASIS;
        self.kv_err[slot] = 0.0;
        for t in toks {
            self.push_token(slot, t);
        }
        Ok(())
    }

    fn verify(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        if tokens.len() != self.n_slots || pos0.len() != self.n_slots || active.len() != self.n_slots
        {
            bail!("mock engine: verify arity mismatch ({} slots)", self.n_slots);
        }
        if self.block_size.is_some() {
            bail!("mock engine: paged engine verified without block tables (use verify_paged)");
        }
        // Its own counter pair, *not* steps/prefill_calls: verify windows
        // must stay distinguishable from prompt prefill (and from plain
        // decode) in every budget-compliance assertion.
        self.verify_calls += 1;
        self.draft_tokens_verified += (0..self.n_slots)
            .filter(|&b| active[b] && !tokens[b].is_empty())
            .map(|b| tokens[b].len() - 1)
            .sum::<usize>();
        let mut out = vec![Vec::new(); self.n_slots];
        for b in 0..self.n_slots {
            if !active[b] || tokens[b].is_empty() {
                continue;
            }
            if pos0[b] as usize != self.history[b].len() {
                bail!(
                    "mock engine: slot {b} verified at pos {} but holds {} tokens \
                     (scheduler position tracking broken, or slot reused without reset)",
                    pos0[b],
                    self.history[b].len()
                );
            }
            if self.history[b].len() + tokens[b].len() > self.max_seq {
                bail!("mock engine: slot {b} verify past cache ({} positions)", self.max_seq);
            }
            // One logits row per fed token, each computed after its token
            // lands — byte-identical to the same tokens fed through
            // sequential decode steps (the speculative correctness anchor).
            for t in tokens[b].clone() {
                self.push_token(b, t);
                out[b].push(self.slot_logits(b, t));
            }
        }
        Ok(out)
    }

    fn verify_paged(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
        tables: &[Vec<i32>],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        if tokens.len() != self.n_slots
            || pos0.len() != self.n_slots
            || active.len() != self.n_slots
            || tables.len() != self.n_slots
        {
            bail!("mock engine: paged verify arity mismatch ({} slots)", self.n_slots);
        }
        if self.block_size.is_none() {
            bail!("mock engine: dense engine got block tables (build with with_block_pool)");
        }
        self.verify_calls += 1;
        self.draft_tokens_verified += (0..self.n_slots)
            .filter(|&b| active[b] && !tokens[b].is_empty())
            .map(|b| tokens[b].len() - 1)
            .sum::<usize>();
        let writes: Vec<(usize, usize)> = (0..self.n_slots)
            .map(|b| if active[b] { (pos0[b] as usize, tokens[b].len()) } else { (0, 0) })
            .collect();
        self.check_exclusive_writes(&writes, tables)?;
        let mut out = vec![Vec::new(); self.n_slots];
        for b in 0..self.n_slots {
            if !active[b] || tokens[b].is_empty() {
                continue;
            }
            if pos0[b] as usize != self.history[b].len() {
                bail!(
                    "mock engine: slot {b} verified at pos {} but holds {} tokens \
                     (scheduler position tracking broken, or slot reused without reset)",
                    pos0[b],
                    self.history[b].len()
                );
            }
            if self.history[b].len() + tokens[b].len() > self.max_seq {
                bail!("mock engine: slot {b} verify past cache ({} positions)", self.max_seq);
            }
            for t in 0..tokens[b].len() {
                let tok = tokens[b][t];
                self.paged_write(b, pos0[b] as usize + t, tok, &tables[b])?;
                self.push_token(b, tok);
                out[b].push(self.slot_logits(b, tok));
            }
        }
        self.check_all_views(tables)?;
        Ok(out)
    }

    fn rewind(&mut self, slot: usize, new_len: usize, table: &[i32]) -> Result<()> {
        if new_len > self.history[slot].len() {
            bail!(
                "mock engine: slot {slot} rewound to {new_len} tokens but holds only {}",
                self.history[slot].len()
            );
        }
        self.history[slot].truncate(new_len);
        // The hash and drift error are positional folds — rebuild them by
        // replay over the surviving prefix (O(len), fine for the mock).
        self.hash[slot] = self.history[slot].iter().fold(HASH_BASIS, |h, &t| hash_fold(h, t));
        self.kv_err[slot] = self.history[slot]
            .iter()
            .enumerate()
            .map(|(pos, &t)| Self::kv_round_trip_err(t, pos, self.kv_bits))
            .sum();
        if let Some(bs) = self.block_size {
            // Truncate the boundary page so the next write at offset
            // `new_len % bs` lands sequentially; pages wholly past the
            // rewind were released by the scheduler and reset on their next
            // offset-0 write, so they need no touch-up here.
            let off = new_len % bs;
            if off != 0 {
                let j = new_len / bs;
                let phys = table.get(j).copied().unwrap_or(-1);
                if phys < 0 || phys as usize >= self.blocks.len() {
                    bail!(
                        "mock engine: slot {slot} rewind to {new_len} through unmapped \
                         boundary page (table[{j}] = {phys})"
                    );
                }
                let page = &mut self.blocks[phys as usize];
                if page.len() < off {
                    bail!(
                        "mock engine: slot {slot} rewind boundary page {phys} holds {} \
                         tokens, expected at least {off}",
                        page.len()
                    );
                }
                page.truncate(off);
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Seeded chaos wrapper: deterministic fault injection over any engine
// ---------------------------------------------------------------------------

/// A seeded fault-injecting wrapper around any [`DecodeEngine`].
///
/// Every intercepted engine call (`step`, `step_paged`, `prefill`,
/// `prefill_paged`, `adopt_prefix`) first consults a deterministic fault
/// schedule; a scheduled fault returns a [`ServeError`] **before the inner
/// engine runs**, so the inner engine's state *and its counters* are
/// exactly what they were before the call — the contract the scheduler's
/// retry path depends on (a retried call sees identical pre-call state,
/// and a mock's `steps`/`prefill_calls` only count calls that really ran).
///
/// Determinism protocol (the sim oracle replays this draw for draw):
/// the schedule is a pure function of the *intercepted-call sequence* —
/// each call consumes exactly **three** PRNG draws from the seeded
/// [`Prng`], whether or not it faults:
///
/// 1. fault trigger: `uniform() < rate` (overridden to "fault" while a
///    burst is draining);
/// 2. fault kind: per-slot vs step-wide (`uniform() < 0.5`);
/// 3. victim pick: an index into the call's active-slot set.
///
/// A triggered fault arms `burst - 1` forced follow-up faults (burst = 1,
/// the default, means isolated faults). `adopt_prefix` faults are always
/// blamed on the adopting slot (draws 2 and 3 are consumed and ignored),
/// and a call with no active slot degrades to step-wide.
pub struct FaultInjector<E: DecodeEngine> {
    inner: E,
    rng: Prng,
    rate: f64,
    burst: usize,
    burst_left: usize,
    /// Intercepted engine calls so far — the schedule's clock.
    pub calls: u64,
    /// Step-wide faults returned so far.
    pub step_faults: usize,
    /// Per-slot faults returned so far.
    pub slot_faults: usize,
}

impl<E: DecodeEngine> FaultInjector<E> {
    /// Wrap `inner` with a fault schedule seeded by `seed` at `rate`
    /// (probability per intercepted call, 0.0 = never fault).
    pub fn new(inner: E, seed: u64, rate: f64) -> Self {
        Self {
            inner,
            rng: Prng::new(seed),
            rate,
            burst: 1,
            burst_left: 0,
            calls: 0,
            step_faults: 0,
            slot_faults: 0,
        }
    }

    /// Each triggered fault forces the next `burst - 1` intercepted calls
    /// to fault as well (correlated-failure bursts).
    pub fn with_burst(mut self, burst: usize) -> Self {
        self.burst = burst.max(1);
        self
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }

    /// Consume the call's three schedule draws; `(fault, per_slot, pick)`.
    fn roll(&mut self) -> (bool, bool, f32) {
        self.calls += 1;
        let trigger = (self.rng.uniform() as f64) < self.rate;
        let per_slot = self.rng.uniform() < 0.5;
        let pick = self.rng.uniform();
        let fault = if self.burst_left > 0 {
            self.burst_left -= 1;
            true
        } else if trigger {
            self.burst_left = self.burst - 1;
            true
        } else {
            false
        };
        (fault, per_slot, pick)
    }

    /// Fault decision for a batch call over `active` lanes.
    fn decide(&mut self, active: &[bool]) -> Option<ServeError> {
        let (fault, per_slot, pick) = self.roll();
        if !fault {
            return None;
        }
        let victims: Vec<usize> = (0..active.len()).filter(|&b| active[b]).collect();
        if per_slot && !victims.is_empty() {
            let k = ((pick * victims.len() as f32) as usize).min(victims.len() - 1);
            self.slot_faults += 1;
            Some(ServeError::Slot { slot: victims[k], what: "injected fault".into() })
        } else {
            self.step_faults += 1;
            Some(ServeError::Transient { what: "injected fault".into() })
        }
    }

    /// Fault decision for `adopt_prefix`: always blamed on the adopter.
    fn decide_adopt(&mut self, slot: usize) -> Option<ServeError> {
        let (fault, _, _) = self.roll();
        if !fault {
            return None;
        }
        self.slot_faults += 1;
        Some(ServeError::Slot { slot, what: "injected adopt fault".into() })
    }
}

impl<E: DecodeEngine> DecodeEngine for FaultInjector<E> {
    fn slots(&self) -> usize {
        self.inner.slots()
    }

    fn max_seq(&self) -> usize {
        self.inner.max_seq()
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32], active: &[bool]) -> Result<Vec<Vec<f32>>> {
        if let Some(e) = self.decide(active) {
            return Err(e.into());
        }
        self.inner.step(tokens, pos, active)
    }

    fn prefill_chunk(&self) -> usize {
        self.inner.prefill_chunk()
    }

    fn prefill(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<f32>>> {
        // Intercept once per scheduler-level call, then delegate to the
        // inner engine's own prefill (never the by-steps default, which
        // would re-enter `self.step` and consume extra schedule draws).
        if let Some(e) = self.decide(active) {
            return Err(e.into());
        }
        self.inner.prefill(tokens, pos0, active)
    }

    fn reset_slot(&mut self, slot: usize) {
        self.inner.reset_slot(slot);
    }

    fn kv_block_size(&self) -> Option<usize> {
        self.inner.kv_block_size()
    }

    fn kv_blocks(&self) -> usize {
        self.inner.kv_blocks()
    }

    fn kv_bits(&self) -> f32 {
        self.inner.kv_bits()
    }

    fn step_paged(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[bool],
        tables: &[Vec<i32>],
    ) -> Result<Vec<Vec<f32>>> {
        if let Some(e) = self.decide(active) {
            return Err(e.into());
        }
        self.inner.step_paged(tokens, pos, active, tables)
    }

    fn prefill_paged(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
        tables: &[Vec<i32>],
    ) -> Result<Vec<Vec<f32>>> {
        if let Some(e) = self.decide(active) {
            return Err(e.into());
        }
        self.inner.prefill_paged(tokens, pos0, active, tables)
    }

    fn adopt_prefix(&mut self, slot: usize, table: &[i32], cached: usize) -> Result<()> {
        if let Some(e) = self.decide_adopt(slot) {
            return Err(e.into());
        }
        self.inner.adopt_prefix(slot, table, cached)
    }

    fn verify(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        // One interception per scheduler-level verify, then the inner
        // engine's own verify — never the by-steps default, which would
        // re-enter `self.step` and consume extra schedule draws (same
        // rationale as `prefill`).
        if let Some(e) = self.decide(active) {
            return Err(e.into());
        }
        self.inner.verify(tokens, pos0, active)
    }

    fn verify_paged(
        &mut self,
        tokens: &[Vec<i32>],
        pos0: &[i32],
        active: &[bool],
        tables: &[Vec<i32>],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        if let Some(e) = self.decide(active) {
            return Err(e.into());
        }
        self.inner.verify_paged(tokens, pos0, active, tables)
    }

    fn rewind(&mut self, slot: usize, new_len: usize, table: &[i32]) -> Result<()> {
        // Rollback is part of fault *recovery* bookkeeping, not an engine
        // call the chaos schedule should be able to fail: no draws, plain
        // forward (also keeps the draw-for-draw oracle protocol at exactly
        // three draws per intercepted call).
        self.inner.rewind(slot, new_len, table)
    }
}

// ---------------------------------------------------------------------------
// Single-request convenience session (paper Table 6 / Fig. 7 harnesses)
// ---------------------------------------------------------------------------

/// One active generation with its KV cache over a B=1 decode artifact.
/// Kept for the latency harnesses and the legacy `Server`; the batched
/// serving path goes through [`PjrtEngine`] + [`super::Scheduler`]. The
/// artifact binding and step mechanics are shared with [`PjrtEngine`]
/// through [`DecodeBinding`].
pub struct GenerationSession<'e> {
    exe: &'e Executable,
    bind: DecodeBinding,
    pub max_seq: usize,
    pub pos: usize,
    pub step_times: Samples,
}

impl<'e> GenerationSession<'e> {
    pub fn new(exe: &'e Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let bind = DecodeBinding::new(exe, weights, qcfg)?;
        if bind.n_slots != 1 {
            bail!(
                "{}: GenerationSession is single-request; artifact has {} slots \
                 (use PjrtEngine + Scheduler)",
                exe.label,
                bind.n_slots
            );
        }
        let max_seq = bind.max_seq;
        Ok(Self { exe, bind, max_seq, pos: 0, step_times: Samples::new() })
    }

    /// Feed one token, advance the cache, return the logits (V,).
    pub fn step(&mut self, token: u8) -> Result<Vec<f32>> {
        if self.pos >= self.max_seq {
            bail!("KV cache full ({} positions)", self.max_seq);
        }
        let t0 = Instant::now();
        let logits = self.bind.step(self.exe, &[token as i32], &[self.pos as i32], None)?;
        self.pos += 1;
        self.step_times.push(t0.elapsed().as_secs_f64() * 1e6);
        Ok(logits)
    }

    /// Greedy generation from a byte prompt.
    pub fn generate(&mut self, prompt: &[u8], n_new: usize) -> Result<Vec<u8>> {
        let mut last = Vec::new();
        for &b in prompt {
            last = self.step(b)?;
        }
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            if self.pos >= self.max_seq {
                break;
            }
            let next = super::sampling::argmax(&last) as u8;
            out.push(next);
            last = self.step(next)?;
        }
        Ok(out)
    }

    pub fn ms_per_token(&self) -> f64 {
        self.step_times.mean_us() / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(DecodeVariant::Fp.artifact(), "decode_fp");
        assert_eq!(DecodeVariant::QuantHad.artifact_batched(1), "decode_had");
        assert_eq!(DecodeVariant::QuantNoHad.artifact_batched(8), "decode_nohad_b8");
    }

    #[test]
    fn mock_is_deterministic_and_slot_independent() {
        let mut a = MockEngine::new(2, 16, 64);
        let mut b = MockEngine::new(4, 16, 64);
        // Same history in slot 0 of engine A and slot 3 of engine B.
        let la = a.step(&[7, 9], &[0, 0], &[true, true]).unwrap();
        let lb = b
            .step(&[1, 2, 3, 7], &[0, 0, 0, 0], &[true, true, true, true])
            .unwrap();
        assert_eq!(la[0], lb[3]);
        assert_ne!(la[0], la[1]);
    }

    #[test]
    fn mock_rejects_position_drift() {
        let mut e = MockEngine::new(1, 16, 32);
        e.step(&[5], &[0], &[true]).unwrap();
        // Correct pos is 1; claiming 0 again must fail loudly.
        assert!(e.step(&[6], &[0], &[true]).is_err());
        // After a reset the slot restarts at 0.
        e.reset_slot(0);
        e.step(&[6], &[0], &[true]).unwrap();
    }

    #[test]
    fn mock_enforces_capacity() {
        let mut e = MockEngine::new(1, 2, 8);
        e.step(&[1], &[0], &[true]).unwrap();
        e.step(&[1], &[1], &[true]).unwrap();
        assert!(e.step(&[1], &[2], &[true]).is_err());
    }

    #[test]
    fn mock_inactive_slots_untouched() {
        let mut e = MockEngine::new(2, 8, 16);
        let out = e.step(&[3, 0], &[0, 0], &[true, false]).unwrap();
        assert_eq!(out[1].len(), 0);
        assert_eq!(e.history[1].len(), 0);
        assert_eq!(e.history[0].len(), 1);
    }

    #[test]
    fn prefill_artifact_names() {
        assert_eq!(DecodeVariant::Fp.artifact_prefill(4, 16), "prefill_fp_b4_t16");
        assert_eq!(DecodeVariant::QuantHad.artifact_prefill(8, 64), "prefill_had_b8_t64");
    }

    #[test]
    fn label_variant_extraction() {
        assert_eq!(label_variant("sq-2m/decode_nohad_b4"), Some("nohad"));
        assert_eq!(label_variant("sq-2m/prefill_fp_b4_t16"), Some("fp"));
        assert_eq!(label_variant("decode_had"), Some("had"));
        assert_eq!(label_variant("sq-2m/fwd_eval_nohad"), None);
    }

    #[test]
    fn mock_prefill_equals_step_loop() {
        // One prefill call == the same tokens fed one step at a time: same
        // final logits, same history (mock logits are a pure function of
        // history, mirroring the L2 graph equivalence proven in pytest).
        let prompt = [5i32, 9, 2, 7, 1];
        let mut a = MockEngine::new(2, 32, 64).with_prefill_chunk(8);
        let la = a
            .prefill(&[prompt.to_vec(), Vec::new()], &[0, 0], &[true, false])
            .unwrap();
        let mut b = MockEngine::new(2, 32, 64);
        let mut lb = Vec::new();
        for (j, &t) in prompt.iter().enumerate() {
            lb = b.step(&[t, 0], &[j as i32, 0], &[true, false]).unwrap();
        }
        assert_eq!(la[0], lb[0]);
        assert_eq!(la[1].len(), 0);
        assert_eq!(a.history[0], b.history[0]);
        assert_eq!(a.prefill_calls, 1);
        assert_eq!(a.steps, 0);
    }

    #[test]
    fn default_prefill_falls_back_to_decode_steps() {
        // An engine without a prefill graph (chunk 1) uses the trait's
        // step-loop fallback — and must produce the identical result.
        let prompt = [3i32, 11, 4];
        let mut a = MockEngine::new(1, 16, 32);
        assert_eq!(a.prefill_chunk(), 1);
        // Route through the fallback explicitly (MockEngine's own override
        // would short-circuit it).
        let la = super::prefill_by_steps(&mut a, &[prompt.to_vec()], &[0], &[true]).unwrap();
        let mut b = MockEngine::new(1, 16, 32).with_prefill_chunk(4);
        let lb = b.prefill(&[prompt.to_vec()], &[0], &[true]).unwrap();
        assert_eq!(la[0], lb[0]);
        assert_eq!(a.steps, 3);
        assert_eq!(b.prefill_calls, 1);
    }

    #[test]
    fn mock_counts_prefill_tokens_per_call() {
        // The budget observable: total fed tokens and the largest single
        // call, summed over slots (inactive lanes don't count).
        let mut e = MockEngine::new(2, 32, 64).with_prefill_chunk(8);
        e.prefill(&[vec![1, 2, 3], vec![4, 5]], &[0, 0], &[true, true]).unwrap();
        assert_eq!(e.prefill_tokens_fed, 5);
        assert_eq!(e.max_prefill_call_tokens, 5);
        e.prefill(&[vec![6], vec![9, 9]], &[3, 0], &[true, false]).unwrap();
        assert_eq!(e.prefill_tokens_fed, 6, "inactive lane must not count");
        assert_eq!(e.max_prefill_call_tokens, 5);
    }

    #[test]
    fn mock_prefill_rejects_oversized_chunk_and_position_drift() {
        let mut e = MockEngine::new(1, 16, 32).with_prefill_chunk(2);
        assert!(e.prefill(&[vec![1, 2, 3]], &[0], &[true]).is_err());
        e.prefill(&[vec![1, 2]], &[0], &[true]).unwrap();
        // pos0 must equal the tokens already held.
        assert!(e.prefill(&[vec![3]], &[0], &[true]).is_err());
        e.reset_slot(0);
        e.prefill(&[vec![3]], &[0], &[true]).unwrap();
    }

    #[test]
    fn mock_prefill_enforces_capacity() {
        let mut e = MockEngine::new(1, 3, 8).with_prefill_chunk(4);
        assert!(e.prefill(&[vec![1, 2, 3, 4]], &[0], &[true]).is_err());
        e.prefill(&[vec![1, 2, 3]], &[0], &[true]).unwrap();
    }

    #[test]
    fn incremental_hash_matches_recomputed_logits() {
        // Satellite regression: the per-step incremental hash must produce
        // logits bit-identical to rehashing the history from scratch, for
        // every prefix, across resets, on both the step and prefill paths.
        let mut e = MockEngine::new(2, 64, 48).with_prefill_chunk(4);
        let mut p = Prng::new(17);
        let mut hist: Vec<i32> = Vec::new();
        for step in 0..40 {
            let t = p.below(48) as i32;
            let out = e
                .prefill(&[vec![t], Vec::new()], &[step, 0], &[true, false])
                .unwrap();
            hist.push(t);
            assert_eq!(out[0], MockEngine::logits_for(&hist, 48), "step {step}");
        }
        e.reset_slot(0);
        let chunk: Vec<i32> = (0..4).map(|_| p.below(48) as i32).collect();
        let out = e.prefill(&[chunk.clone(), Vec::new()], &[0, 0], &[true, false]).unwrap();
        assert_eq!(out[0], MockEngine::logits_for(&chunk, 48));
        let out = e.step(&[9, 0], &[4, 0], &[true, false]).unwrap();
        let mut full = chunk;
        full.push(9);
        assert_eq!(out[0], MockEngine::logits_for(&full, 48));
    }

    // -- paged (block-pool) mock -----------------------------------------

    fn identity_tables(slots: usize, n_logical: usize) -> Vec<Vec<i32>> {
        (0..slots)
            .map(|b| (0..n_logical).map(|j| (b * n_logical + j) as i32).collect())
            .collect()
    }

    #[test]
    fn paged_mock_matches_dense_logits() {
        // Same token stream through the dense and the paged mock (identity
        // tables): logits must be bit-identical — the mock analogue of the
        // L2 paged-vs-dense bit-equality proven in pytest.
        let bs = 4;
        let mut dense = MockEngine::new(2, 16, 32);
        let mut paged = MockEngine::new(2, 16, 32).with_block_pool(8, bs);
        assert_eq!(paged.kv_block_size(), Some(bs));
        assert_eq!(paged.kv_blocks(), 8);
        let tables = identity_tables(2, 4);
        for pos in 0..10 {
            let toks = [pos as i32 * 3 % 32, (pos as i32 * 7 + 1) % 32];
            let a = dense.step(&toks, &[pos, pos], &[true, true]).unwrap();
            let b = paged.step_paged(&toks, &[pos, pos], &[true, true], &tables).unwrap();
            assert_eq!(a, b, "pos {pos}");
        }
    }

    #[test]
    fn paged_mock_prefill_matches_step_loop_across_page_boundary() {
        let bs = 4;
        let prompt = [5i32, 9, 2, 7, 1, 3]; // 6 tokens: crosses a page edge
        let tables = identity_tables(1, 4);
        let mut a = MockEngine::new(1, 16, 64).with_block_pool(4, bs).with_prefill_chunk(8);
        let la = a.prefill_paged(&[prompt.to_vec()], &[0], &[true], &tables).unwrap();
        let mut b = MockEngine::new(1, 16, 64).with_block_pool(4, bs);
        let mut lb = Vec::new();
        for (j, &t) in prompt.iter().enumerate() {
            lb = b.step_paged(&[t], &[j as i32], &[true], &tables).unwrap();
        }
        assert_eq!(la[0], lb[0]);
        assert_eq!(a.prefill_calls, 1);
        assert_eq!(b.steps, 6);
    }

    #[test]
    fn paged_mock_scattered_tables_work_and_pages_are_reusable() {
        let bs = 2;
        let mut e = MockEngine::new(1, 8, 16).with_block_pool(4, bs);
        // Scrambled mapping: logical pages 0..3 -> physical 3,1,0,2.
        let t = vec![vec![3, 1, 0, 2]];
        for pos in 0..5 {
            e.step_paged(&[pos + 1], &[pos], &[true], &t).unwrap();
        }
        // New occupant with a different mapping reuses the pages; writes at
        // offset 0 reset them.
        e.reset_slot(0);
        let t2 = vec![vec![0, 2, 1, 3]];
        let out = e.step_paged(&[11], &[0], &[true], &t2).unwrap();
        assert_eq!(out[0], MockEngine::logits_for(&[11], 16));
    }

    #[test]
    fn paged_mock_rejects_unmapped_writes_aliasing_and_dense_mixups() {
        let bs = 2;
        // Hole: table entry >= n_blocks is the unallocated sentinel.
        let mut e = MockEngine::new(2, 8, 16).with_block_pool(4, bs);
        let holes = vec![vec![4, 4, 4, 4], vec![4, 4, 4, 4]];
        assert!(e.step_paged(&[1, 0], &[0, 0], &[true, false], &holes).is_err());
        // Aliasing: two active slots mapping the same physical page.
        let mut e = MockEngine::new(2, 8, 16).with_block_pool(4, bs);
        let aliased = vec![vec![0, 1, 2, 3], vec![0, 1, 2, 3]];
        assert!(e.step_paged(&[1, 2], &[0, 0], &[true, true], &aliased).is_err());
        // Paged engine without tables / dense engine with tables.
        let mut e = MockEngine::new(1, 8, 16).with_block_pool(4, bs);
        assert!(e.step(&[1], &[0], &[true]).is_err());
        let mut d = MockEngine::new(1, 8, 16);
        assert!(d.step_paged(&[1], &[0], &[true], &identity_tables(1, 4)).is_err());
    }

    #[test]
    fn adopt_prefix_rebuilds_history_from_shared_pages() {
        let bs = 4;
        let mut e = MockEngine::new(2, 32, 64).with_block_pool(8, bs);
        // Slot 0 fills physical pages 0 and 1 with 8 tokens.
        let tables = vec![vec![0, 1], Vec::new()];
        for p in 0..8 {
            e.step_paged(&[p + 10, 0], &[p, 0], &[true, false], &tables).unwrap();
        }
        // Slot 1 adopts the first page read-only and writes its own page 2:
        // logits must equal a from-scratch history over the shared tokens.
        let t1 = vec![0, 2];
        e.adopt_prefix(1, &t1, 4).unwrap();
        let tables = vec![vec![0, 1], t1];
        let out = e.step_paged(&[0, 14], &[8, 4], &[false, true], &tables).unwrap();
        assert_eq!(out[1], MockEngine::logits_for(&[10, 11, 12, 13, 14], 64));
        // Adopting through an unmapped or partial page fails loudly.
        assert!(e.adopt_prefix(1, &[7], 4).is_err(), "page 7 was never written");
        assert!(e.adopt_prefix(1, &[2], 4).is_err(), "page 2 holds one token, not 4");
        let mut d = MockEngine::new(1, 8, 16);
        assert!(d.adopt_prefix(0, &[0], 0).is_err(), "dense engine has no pages");
    }

    #[test]
    fn mock_allows_shared_reads_but_rejects_shared_writes() {
        let bs = 4;
        let mut e = MockEngine::new(2, 32, 64).with_block_pool(8, bs);
        let warm = vec![vec![0, 1], Vec::new()];
        for p in 0..5 {
            e.step_paged(&[p + 10, 0], &[p, 0], &[true, false], &warm).unwrap();
        }
        // Slot 1 shares page 0 read-only (its writes land in page 2):
        // legal, and both slots step together.
        e.adopt_prefix(1, &[0, 2], 4).unwrap();
        let shared = vec![vec![0, 1], vec![0, 2]];
        e.step_paged(&[15, 40], &[5, 4], &[true, true], &shared).unwrap();
        // A table that makes slot 1 WRITE page 0 — which slot 0 still
        // attends over — is a copy-on-write violation.
        let mut e = MockEngine::new(2, 32, 64).with_block_pool(8, bs);
        let warm = vec![vec![0, 1], Vec::new()];
        for p in 0..5 {
            e.step_paged(&[p + 10, 0], &[p, 0], &[true, false], &warm).unwrap();
        }
        let clobber = vec![vec![0, 1], vec![0]];
        let err = e
            .step_paged(&[15, 99], &[5, 0], &[true, true], &clobber)
            .unwrap_err();
        assert!(err.to_string().contains("read-only"), "{err:#}");
        // Same guard on the prefill path.
        let mut e = MockEngine::new(2, 32, 64).with_block_pool(8, bs).with_prefill_chunk(4);
        e.prefill_paged(&[vec![1, 2, 3, 4], Vec::new()], &[0, 0], &[true, false], &warm)
            .unwrap();
        let err = e
            .prefill_paged(&[Vec::new(), vec![7, 8]], &[0, 0], &[false, true], &clobber)
            .unwrap_err();
        assert!(err.to_string().contains("read-only"), "{err:#}");
    }

    // -- quantized KV storage (--kv-bits) ---------------------------------

    #[test]
    fn kv16_is_byte_identical_to_default_engine() {
        // Explicit 16-bit KV must be a no-op: same logits, zero accumulated
        // error, Exact page payloads — the dense-fallback/pre-PR contract.
        let tables = identity_tables(1, 4);
        let mut a = MockEngine::new(1, 16, 64).with_block_pool(4, 4);
        let mut b = MockEngine::new(1, 16, 64).with_block_pool(4, 4).with_kv_bits(16.0);
        for pos in 0..10 {
            let t = (pos * 5 + 3) as i32 % 64;
            let la = a.step_paged(&[t], &[pos as i32], &[true], &tables).unwrap();
            let lb = b.step_paged(&[t], &[pos as i32], &[true], &tables).unwrap();
            assert_eq!(la, lb, "pos {pos}");
            assert_eq!(lb[0], MockEngine::logits_for(&b.history[0], 64));
        }
        assert_eq!(b.kv_err[0], 0.0);
        assert_eq!(b.kv_bits(), 16.0);
    }

    #[test]
    fn kv4_drifts_logits_but_dense_and_paged_agree() {
        // Quantized KV must change logits vs fp (that's the point), but
        // dense and paged storage at the same width stay bit-identical —
        // the storage layout is not allowed to alter the math.
        let tables = identity_tables(1, 8);
        let mut fp = MockEngine::new(1, 64, 48);
        let mut dense4 = MockEngine::new(1, 64, 48).with_kv_bits(4.0);
        let mut paged4 = MockEngine::new(1, 64, 48).with_block_pool(8, 8).with_kv_bits(4.0);
        let mut hist = Vec::new();
        let mut diverged = false;
        for pos in 0..40 {
            let t = (pos * 11 + 2) as i32 % 48;
            hist.push(t);
            let lf = fp.step(&[t], &[pos as i32], &[true]).unwrap();
            let ld = dense4.step(&[t], &[pos as i32], &[true]).unwrap();
            let lp = paged4.step_paged(&[t], &[pos as i32], &[true], &tables).unwrap();
            assert_eq!(ld, lp, "pos {pos}: dense vs paged int4");
            assert_eq!(ld[0], MockEngine::logits_for_kv(&hist, 48, 4.0), "pos {pos}");
            diverged |= ld[0] != lf[0];
        }
        assert!(diverged, "int4 KV drift never moved a logit");
        assert!(dense4.kv_err[0] > 0.0);
    }

    #[test]
    fn int8_kv_greedy_completion_matches_fp() {
        // The drift coefficient is sized so int8's accumulated row error
        // (~0.25/token, <= 32 over a 128-position history) perturbs any
        // logit by < 1.3 — strictly inside the > 4 gap between the mock's
        // peak (>= 8) and base (< 4) logits. Greedy decoding under int8 KV
        // is therefore *guaranteed* byte-identical to fp, not just likely.
        let mut fp = MockEngine::new(1, 128, 64);
        let mut q8 = MockEngine::new(1, 128, 64).with_kv_bits(8.0);
        let prompt = [7i32, 3, 19, 42];
        let mut la = Vec::new();
        let mut lb = Vec::new();
        for (j, &t) in prompt.iter().enumerate() {
            la = fp.step(&[t], &[j as i32], &[true]).unwrap().remove(0);
            lb = q8.step(&[t], &[j as i32], &[true]).unwrap().remove(0);
        }
        for pos in prompt.len()..120 {
            let ta = crate::serve::sampling::argmax(&la) as i32;
            let tb = crate::serve::sampling::argmax(&lb) as i32;
            assert_eq!(ta, tb, "pos {pos}: int8 greedy diverged from fp");
            la = fp.step(&[ta], &[pos as i32], &[true]).unwrap().remove(0);
            lb = q8.step(&[tb], &[pos as i32], &[true]).unwrap().remove(0);
        }
        assert!(q8.kv_err[0] > 0.0, "int8 accrues real (bounded) error");
    }

    #[test]
    fn pages_store_round_tripped_payloads_and_measured_bytes() {
        // Fill one physical page at each width and check (a) the stored
        // payload dequantizes to the canonical round trip, not the raw row,
        // and (b) measured resident bytes match the per-page accounting
        // formula (x2 for the K and V sides the real pool holds).
        let bs = 16;
        for &(bits, per_token) in
            &[(4.0f32, 64 + 4 * 2), (8.0, 128 + 4 * 2), (16.0, MOCK_KV_DIM * 2)]
        {
            let mut e = MockEngine::new(1, 32, 64).with_block_pool(2, bs).with_kv_bits(bits);
            let tables = identity_tables(1, 2);
            for pos in 0..bs {
                e.step_paged(&[(pos * 3 + 1) as i32], &[pos as i32], &[true], &tables).unwrap();
            }
            assert_eq!(e.resident_kv_bytes(), bs * per_token, "bits {bits}");
            assert_eq!(
                2 * e.resident_kv_bytes(),
                crate::serve::blocks::kv_memory_bytes(1, bs, 1, 4, 32, bits as f64, true),
                "bits {bits}: measured pool bytes vs accounting formula"
            );
            let entry = &e.blocks[0][3];
            let deq = entry.kv.dequantize(entry.token, 3);
            let raw = MockEngine::mock_kv_row(entry.token, 3);
            assert_eq!(deq.len(), MOCK_KV_DIM);
            if bits < 16.0 {
                assert_ne!(deq, raw, "bits {bits}: storage must be lossy");
                let err: f32 =
                    raw.iter().zip(&deq).map(|(x, y)| (x - y).abs()).sum();
                assert_eq!(err, MockEngine::kv_round_trip_err(entry.token, 3, bits));
            } else {
                assert_eq!(deq, raw, "16-bit storage is exact");
            }
        }
    }

    #[test]
    fn int4_row_error_dominates_int8() {
        // Per-token row error ordering the drift model rests on: int4 ~ 18x
        // int8 (quant step 1/7 vs 1/127 on a [-1, 1) row).
        let e4 = MockEngine::kv_round_trip_err(13, 5, 4.0);
        let e8 = MockEngine::kv_round_trip_err(13, 5, 8.0);
        assert!(e8 > 0.0);
        assert!(e4 > 8.0 * e8, "int4 err {e4} vs int8 err {e8}");
        // And int8 over a full history stays inside the greedy-gap bound
        // the drift coefficient was sized for.
        let worst: f32 =
            (0..128).map(|p| MockEngine::kv_round_trip_err(p as i32 % 64, p, 8.0)).sum();
        assert!(MOCK_KV_DRIFT * worst < 2.0, "int8 drift bound broke: {worst}");
    }

    #[test]
    fn adopt_prefix_rejects_mixed_width_pages_and_rebuilds_kv_err() {
        let bs = 4;
        // Donor writes 4 tokens at int4; an int4 adopter inherits both the
        // history and the accumulated storage error of the shared prefix.
        let mut e = MockEngine::new(2, 32, 64).with_block_pool(8, bs).with_kv_bits(4.0);
        let tables = vec![vec![0, 1], Vec::new()];
        for p in 0..4 {
            e.step_paged(&[p + 20, 0], &[p, 0], &[true, false], &tables).unwrap();
        }
        let donor_err = e.kv_err[0];
        assert!(donor_err > 0.0);
        e.adopt_prefix(1, &[0, 2], 4).unwrap();
        assert_eq!(e.kv_err[1], donor_err, "adopter inherits the prefix's storage error");
        // An engine at a different width must refuse the same pages: its
        // graphs would dequantize them with the wrong codec.
        let mut w = MockEngine::new(2, 32, 64).with_block_pool(8, bs).with_kv_bits(8.0);
        for p in 0..4 {
            w.step_paged(&[p + 20, 0], &[p, 0], &[true, false], &tables).unwrap();
        }
        w.kv_bits = 4.0; // simulate adopting a page stored at another width
        let err = w.adopt_prefix(1, &[0, 2], 4).unwrap_err();
        assert!(err.to_string().contains("round trip"), "{err:#}");
    }

    #[test]
    fn paged_artifact_names() {
        assert_eq!(DecodeVariant::QuantNoHad.artifact_paged(4), "decode_nohad_paged_b4");
        assert_eq!(
            DecodeVariant::QuantHad.artifact_prefill_paged(8, 16),
            "prefill_had_paged_b8_t16"
        );
        assert_eq!(label_variant("sq-2m/decode_nohad_paged_b4"), Some("nohad"));
        assert_eq!(label_variant("prefill_fp_paged_b4_t16"), Some("fp"));
    }

    #[test]
    fn fault_injector_rate_zero_is_pure_passthrough() {
        let mut plain = MockEngine::new(2, 16, 64);
        let mut wrapped = FaultInjector::new(MockEngine::new(2, 16, 64), 42, 0.0);
        let a = plain.step(&[7, 9], &[0, 0], &[true, true]).unwrap();
        let b = wrapped.step(&[7, 9], &[0, 0], &[true, true]).unwrap();
        assert_eq!(a, b);
        assert_eq!(wrapped.calls, 1);
        assert_eq!(wrapped.step_faults + wrapped.slot_faults, 0);
        assert_eq!(wrapped.inner().steps, 1);
    }

    #[test]
    fn fault_injector_schedule_is_deterministic_across_reruns() {
        let run = |seed: u64| {
            let mut e = FaultInjector::new(MockEngine::new(1, 64, 64), seed, 0.3);
            let mut faults = Vec::new();
            let mut pos = 0i32;
            for i in 0..40 {
                match e.step(&[pos % 60], &[pos], &[true]) {
                    Ok(_) => pos += 1,
                    Err(err) => {
                        let se = err.downcast::<ServeError>().expect("injected ServeError");
                        faults.push((i, se));
                    }
                }
            }
            (faults, e.inner().steps)
        };
        assert_eq!(run(5), run(5), "same seed must replay the same schedule");
        let (faults, steps) = run(5);
        assert!(!faults.is_empty(), "rate 0.3 over 40 calls must fault");
        // Only the calls that really ran reached the inner engine.
        assert_eq!(steps, 40 - faults.len());
    }

    #[test]
    fn fault_injector_fails_before_inner_state_or_counters_move() {
        // Burst forces the very first call to fault (rate 1.0): the inner
        // engine must be untouched, and the retry must then see the exact
        // pre-call state once the schedule stops faulting.
        let mut e = FaultInjector::new(MockEngine::new(1, 16, 64), 9, 1.0);
        let err = e.step(&[5], &[0], &[true]).unwrap_err();
        assert!(err.downcast_ref::<ServeError>().is_some());
        assert_eq!(e.inner().steps, 0, "faulted call must not reach the inner engine");
        assert_eq!(e.inner().history[0].len(), 0);
        e.rate = 0.0;
        e.burst_left = 0;
        let ok = e.step(&[5], &[0], &[true]).unwrap();
        assert_eq!(ok[0], MockEngine::new(1, 16, 64).step(&[5], &[0], &[true]).unwrap()[0]);
    }

    #[test]
    fn fault_injector_burst_arms_followup_faults() {
        // rate 1.0, burst 3: calls 1..=3 fault (1 trigger + 2 forced), and
        // with the rate then dropped to 0 the armed burst still drains.
        let mut e = FaultInjector::new(MockEngine::new(1, 16, 64), 1, 1.0).with_burst(3);
        assert!(e.step(&[5], &[0], &[true]).is_err());
        e.rate = 0.0;
        assert!(e.step(&[5], &[0], &[true]).is_err());
        assert!(e.step(&[5], &[0], &[true]).is_err());
        assert!(e.step(&[5], &[0], &[true]).is_ok());
        assert_eq!(e.inner().steps, 1);
    }

    #[test]
    fn fault_injector_adopt_faults_blame_the_adopter() {
        let bs = 4;
        let mut inner = MockEngine::new(2, 32, 64).with_block_pool(8, bs);
        let tables = vec![vec![0, 1], Vec::new()];
        for p in 0..4 {
            inner.step_paged(&[p + 20, 0], &[p, 0], &[true, false], &tables).unwrap();
        }
        let mut e = FaultInjector::new(inner, 3, 1.0);
        let err = e.adopt_prefix(1, &[0, 2], 4).unwrap_err();
        match err.downcast::<ServeError>().expect("injected ServeError") {
            ServeError::Slot { slot, .. } => assert_eq!(slot, 1),
            other => panic!("adopt fault must be per-slot, got {other:?}"),
        }
        assert_eq!(e.inner().history[1].len(), 0, "faulted adopt must not rebuild history");
    }

    #[test]
    fn serve_error_display_and_downcast() {
        let e: anyhow::Error = ServeError::Slot { slot: 3, what: "x".into() }.into();
        assert!(e.to_string().contains("slot 3"));
        assert!(e.downcast_ref::<ServeError>().is_some());
        let f: anyhow::Error = ServeError::Fatal { what: "caches lost".into() }.into();
        assert!(f.to_string().contains("fatal"));
        assert!(
            anyhow::anyhow!("plain").downcast_ref::<ServeError>().is_none(),
            "unclassified errors must not look like ServeErrors"
        );
    }

    #[test]
    fn mock_verify_rows_equal_sequential_steps() {
        // The speculative correctness anchor at engine level: one verify
        // call over [next, d1, d2, d3] returns exactly the logits rows the
        // same four tokens fed through sequential decode steps produce.
        let window = [5i32, 9, 2, 7];
        let mut a = MockEngine::new(2, 32, 64);
        a.step(&[3, 0], &[0, 0], &[true, false]).unwrap();
        let rows = a.verify(&[window.to_vec(), Vec::new()], &[1, 0], &[true, false]).unwrap();
        let mut b = MockEngine::new(2, 32, 64);
        b.step(&[3, 0], &[0, 0], &[true, false]).unwrap();
        for (j, &t) in window.iter().enumerate() {
            let l = b.step(&[t, 0], &[1 + j as i32, 0], &[true, false]).unwrap();
            assert_eq!(rows[0][j], l[0], "row {j} diverges from the sequential step");
        }
        assert_eq!(rows[0].len(), window.len());
        assert_eq!(rows[1].len(), 0, "inactive lane must return no rows");
        assert_eq!(a.history[0], b.history[0]);
    }

    #[test]
    fn mock_verify_counters_stay_off_the_prefill_books() {
        // Satellite: verify calls must be distinguishable from prompt
        // prefill — they get their own counter pair and leave every
        // budget-compliance observable untouched.
        let mut e = MockEngine::new(2, 32, 64).with_prefill_chunk(8);
        e.prefill(&[vec![1, 2, 3], Vec::new()], &[0, 0], &[true, false]).unwrap();
        let (pc, pt, pm, st) =
            (e.prefill_calls, e.prefill_tokens_fed, e.max_prefill_call_tokens, e.steps);
        e.verify(&[vec![4, 5, 6], vec![7]], &[3, 0], &[true, true]).unwrap();
        assert_eq!(e.verify_calls, 1);
        // Lane 0 carried 2 drafts (3 tokens - the 1 a plain step feeds),
        // lane 1 carried 0.
        assert_eq!(e.draft_tokens_verified, 2);
        assert_eq!(e.prefill_calls, pc, "verify must not count as prefill");
        assert_eq!(e.prefill_tokens_fed, pt);
        assert_eq!(e.max_prefill_call_tokens, pm);
        assert_eq!(e.steps, st, "verify must not count as decode steps");
    }

    #[test]
    fn mock_verify_rejects_position_drift_and_capacity() {
        let mut e = MockEngine::new(1, 4, 16);
        e.step(&[1], &[0], &[true]).unwrap();
        assert!(e.verify(&[vec![2]], &[0], &[true]).is_err(), "stale pos0");
        assert!(e.verify(&[vec![2, 3, 4, 5]], &[1], &[true]).is_err(), "past cache");
        e.verify(&[vec![2, 3, 4]], &[1], &[true]).unwrap();
    }

    #[test]
    fn mock_rewind_restores_sequential_state_dense() {
        // Feed 5, rewind to 2, re-feed the same suffix: logits and hash
        // state must be byte-identical to never having speculated at all.
        let toks = [5i32, 9, 2, 7, 1];
        let mut a = MockEngine::new(1, 16, 64);
        for (j, &t) in toks.iter().enumerate() {
            a.step(&[t], &[j as i32], &[true]).unwrap();
        }
        a.rewind(0, 2, &[]).unwrap();
        assert_eq!(a.history[0], &toks[..2]);
        let mut b = MockEngine::new(1, 16, 64);
        for (j, &t) in toks[..2].iter().enumerate() {
            b.step(&[t], &[j as i32], &[true]).unwrap();
        }
        assert_eq!(a.hash[0], b.hash[0], "rewound hash must equal the replayed prefix");
        let la = a.step(&[8], &[2], &[true]).unwrap();
        let lb = b.step(&[8], &[2], &[true]).unwrap();
        assert_eq!(la[0], lb[0]);
        assert!(a.rewind(0, 99, &[]).is_err(), "rewind past the held length must fail");
    }

    #[test]
    fn mock_rewind_truncates_boundary_page_and_replays_identically() {
        // Paged: rewind from pos 7 to pos 5 across a 4-token page boundary
        // truncates the boundary page so the re-fed suffix lands
        // sequentially, and kv drift error is rebuilt (kv_bits 4 so the
        // error term is non-trivial).
        let bs = 4;
        let tables = vec![vec![0, 1]];
        let toks = [5i32, 9, 2, 7, 1, 6, 3];
        let mut a = MockEngine::new(1, 16, 64).with_block_pool(4, bs).with_kv_bits(4.0);
        for (j, &t) in toks.iter().enumerate() {
            a.step_paged(&[t], &[j as i32], &[true], &tables).unwrap();
        }
        a.rewind(0, 5, &tables[0]).unwrap();
        assert_eq!(a.history[0], &toks[..5]);
        assert_eq!(a.blocks[1].len(), 1, "boundary page truncated to 5 % 4 tokens");
        let mut b = MockEngine::new(1, 16, 64).with_block_pool(4, bs).with_kv_bits(4.0);
        for (j, &t) in toks[..5].iter().enumerate() {
            b.step_paged(&[t], &[j as i32], &[true], &tables).unwrap();
        }
        assert_eq!(a.kv_err[0], b.kv_err[0], "drift error must be rebuilt by replay");
        let la = a.step_paged(&[8], &[5], &[true], &tables).unwrap();
        let lb = b.step_paged(&[8], &[5], &[true], &tables).unwrap();
        assert_eq!(la[0], lb[0]);
    }

    #[test]
    fn mock_paged_verify_matches_dense_at_16_bits_and_writes_pages() {
        let bs = 4;
        let tables = vec![vec![0, 1, 2]];
        let mut p = MockEngine::new(1, 16, 64).with_block_pool(4, bs);
        p.step_paged(&[3], &[0], &[true], &tables).unwrap();
        let rows = p.verify_paged(&[vec![5, 9, 2, 7]], &[1], &[true], &tables).unwrap();
        let mut d = MockEngine::new(1, 16, 64);
        d.step(&[3], &[0], &[true]).unwrap();
        let drows = d.verify(&[vec![5, 9, 2, 7]], &[1], &[true]).unwrap();
        assert_eq!(rows[0], drows[0], "paged verify rows must equal dense at 16-bit KV");
        assert_eq!(p.blocks[1].len(), 1, "verify writes land in physical pages");
        assert_eq!(p.verify_calls, 1);
        assert_eq!(p.draft_tokens_verified, 3);
    }

    #[test]
    fn default_verify_falls_back_to_step_loop_keeping_every_row() {
        // Engines without a verify override get the by-steps default — and
        // unlike the prefill fallback it must keep every per-token row.
        let window = [5i32, 9, 2];
        let mut a = MockEngine::new(1, 16, 32);
        let rows = super::verify_by_steps(&mut a, &[window.to_vec()], &[0], &[true]).unwrap();
        assert_eq!(a.steps, 3);
        let mut b = MockEngine::new(1, 16, 32);
        let brows = b.verify(&[window.to_vec()], &[0], &[true]).unwrap();
        assert_eq!(rows[0], brows[0]);
        assert_eq!(b.steps, 0);
    }

    #[test]
    fn fault_injector_intercepts_verify_but_never_rewind() {
        let mut e = FaultInjector::new(MockEngine::new(1, 16, 64), 9, 1.0);
        let err = e.verify(&[vec![5, 6]], &[0], &[true]).unwrap_err();
        assert!(err.downcast_ref::<ServeError>().is_some());
        assert_eq!(e.inner().verify_calls, 0, "faulted verify must not reach the inner engine");
        assert_eq!(e.calls, 1, "verify consumes exactly one schedule slot");
        e.rate = 0.0;
        e.burst_left = 0;
        e.verify(&[vec![5, 6]], &[0], &[true]).unwrap();
        assert_eq!(e.inner().verify_calls, 1);
        // Rollback must never fault and must consume no schedule draws.
        e.rate = 1.0;
        let calls_before = e.calls;
        e.rewind(0, 1, &[]).unwrap();
        assert_eq!(e.calls, calls_before, "rewind is not an intercepted call");
        assert_eq!(e.inner().history[0].len(), 1);
    }
}
