//! Dependency-free HTTP/1.1 + SSE network front for the serving stack.
//!
//! Design constraint: PJRT execution handles are not `Send`, so the
//! [`Scheduler`] can never migrate off the thread that built it. Instead of
//! a framework + channel fan-out, this front is a small non-blocking
//! `TcpListener` poll loop that runs *around* the scheduler on its owning
//! thread: each [`HttpFront::poll`] call accepts sockets, parses requests,
//! admits them, runs **one** `Scheduler::step`, and fans the step's tokens
//! out to the open SSE streams. The scheduler stays put; the sockets come
//! to it.
//!
//! # Protocol
//!
//! * `POST /generate` — JSON body `{"prompt": "...", "max_new_tokens": N,
//!   "seed": S, "sampler": "greedy|temperature|top-k|top-p", "temperature":
//!   T, "top_k": K, "top_p": P, "deadline_ms": D}` (everything but `prompt`
//!   optional). Streams `text/event-stream`:
//!   - `event: token` / `data: {"id":I,"idx":N,"byte":B}` per generated
//!     byte. `idx` is the absolute position in the completion; after an
//!     eviction-restart the scheduler replays the prefix and the front
//!     dedupes on `idx`, so a client never sees a byte twice.
//!   - `event: done` / `data: {completion byte array, reason, ttft_ms,
//!     latency_ms}` terminates the stream, then the connection closes.
//! * `GET /healthz` — queue depth / in-flight / slot capacity as JSON.
//!
//! # Overload policy
//!
//! Admission is gated *before* the scheduler sees the request:
//! 1. per-tenant token bucket (tenant = `x-tenant` header, default
//!    `"anon"`) — empty bucket → `429` with `"rate_limited"`;
//! 2. queue-depth watermark (`shed_depth`) and the scheduler's own queue
//!    capacity — at or past either → `429` with `"overloaded"`.
//!
//! A `429` is always a complete, parseable JSON response; the queue can
//! never grow past `shed_depth`, so overload degrades to fast rejections
//! instead of unbounded buffering.
//!
//! # Disconnects
//!
//! Every poll reads each streaming socket; EOF or a hard error propagates
//! to [`Scheduler::cancel`] *before* the step runs, so a dropped client
//! frees its slot and pages within one poll and never donates in-flight
//! pages to the prefix index (cancel uses `release`, the donation-free
//! teardown path).

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::serve::engine::DecodeEngine;
use crate::serve::sampling::Sampler;
use crate::serve::scheduler::{Completion, GenRequest, Scheduler};
use crate::util::json::{self, Json};

/// Hard cap on a single request head+body; past this the front answers
/// `400` rather than buffering a slow-loris stream forever.
const MAX_REQUEST_BYTES: usize = 64 * 1024;
/// Hard cap on simultaneously open sockets; accepts past it are dropped.
const MAX_CONNS: usize = 1024;

/// Classic token bucket. Pure state machine: refill takes the elapsed time
/// explicitly so tests (and the deterministic sim) drive it without a
/// clock. Starts full, so a fresh tenant gets its full burst.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    capacity: f64,
    tokens: f64,
    rate_per_sec: f64,
}

impl TokenBucket {
    pub fn new(rate_per_sec: f64, capacity: f64) -> Self {
        Self { capacity, tokens: capacity, rate_per_sec }
    }

    /// Credit `elapsed_secs` worth of tokens, saturating at the burst cap.
    pub fn refill(&mut self, elapsed_secs: f64) {
        self.tokens = (self.tokens + elapsed_secs * self.rate_per_sec).min(self.capacity);
    }

    /// Take `n` tokens if available; `false` leaves the bucket untouched.
    pub fn try_take(&mut self, n: f64) -> bool {
        if self.tokens >= n {
            self.tokens -= n;
            true
        } else {
            false
        }
    }

    pub fn available(&self) -> f64 {
        self.tokens
    }
}

/// Front-door policy knobs (`serve --http PORT --rate-limit N
/// --shed-depth D`).
#[derive(Clone, Debug)]
pub struct HttpFrontConfig {
    /// Per-tenant sustained admission rate (requests/sec). `None` disables
    /// rate limiting entirely.
    pub rate_per_sec: Option<f64>,
    /// Per-tenant burst allowance (token-bucket capacity, in requests).
    pub burst: f64,
    /// Shed watermark: a `/generate` arriving while `queue_depth() >=
    /// shed_depth` is answered `429` instead of queued.
    pub shed_depth: usize,
}

impl Default for HttpFrontConfig {
    fn default() -> Self {
        Self { rate_per_sec: None, burst: 8.0, shed_depth: 64 }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ConnState {
    /// Accumulating the request head (+body) in `rbuf`.
    Reading,
    /// SSE response open for scheduler request `id`; `sent` is the
    /// number of token events already written — the replay high-water
    /// mark that dedupes eviction-restart re-emissions.
    Streaming { id: u64, sent: usize },
    /// Response fully generated; flush `wbuf` then close.
    Closing,
    /// Socket is gone; reap without flushing.
    Dead,
}

struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    state: ConnState,
}

enum Action {
    Respond(Vec<u8>),
    Stream(u64),
}

struct TenantBucket {
    bucket: TokenBucket,
    last: Instant,
}

/// The poll-loop HTTP/SSE front. Owns the listener and sockets; borrows
/// the scheduler one [`poll`](Self::poll) at a time.
pub struct HttpFront {
    listener: TcpListener,
    conns: Vec<Conn>,
    cfg: HttpFrontConfig,
    /// Per-token emissions from the scheduler hook land here (id, idx,
    /// byte) and are drained into SSE frames after each step. `Rc` because
    /// the hook closure lives inside the scheduler; neither crosses
    /// threads.
    bus: Rc<RefCell<VecDeque<(u64, usize, u8)>>>,
    buckets: HashMap<String, TenantBucket>,
}

impl HttpFront {
    /// Bind (non-blocking) on `addr`, e.g. `"127.0.0.1:0"` for an
    /// ephemeral test port.
    pub fn bind(addr: &str, cfg: HttpFrontConfig) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(Self {
            listener,
            conns: Vec::new(),
            cfg,
            bus: Rc::new(RefCell::new(VecDeque::new())),
            buckets: HashMap::new(),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Install the per-token emission hook on `sched` so its generated
    /// bytes reach this front's SSE streams. Must be called once before
    /// the first [`poll`](Self::poll); without it streams still open and
    /// close correctly but carry only the `done` event.
    pub fn install_token_hook<E: DecodeEngine>(&self, sched: &mut Scheduler<E>) {
        let bus = Rc::clone(&self.bus);
        sched.set_token_hook(move |id, idx, byte| {
            bus.borrow_mut().push_back((id, idx, byte));
        });
    }

    /// Open sockets (any state).
    pub fn conn_count(&self) -> usize {
        self.conns.len()
    }

    /// Sockets currently mid-SSE-stream.
    pub fn open_streams(&self) -> usize {
        self.conns
            .iter()
            .filter(|c| matches!(c.state, ConnState::Streaming { .. }))
            .count()
    }

    /// One front iteration: accept → read/admit → disconnect-cancel →
    /// step → fan out tokens → flush. Returns the step's completions
    /// (empty when the scheduler was idle). Call in a loop; between
    /// calls the front holds no scheduler borrow.
    pub fn poll<E: DecodeEngine>(&mut self, sched: &mut Scheduler<E>) -> Result<Vec<Completion>> {
        self.accept_new()?;
        self.read_requests(sched)?;
        // Cancels must land before the step so a dropped client's slot is
        // reusable in the same iteration.
        self.check_disconnects(sched)?;
        let done = if sched.is_idle() { Vec::new() } else { sched.step()? };
        self.drain_tokens();
        self.deliver_completions(&done);
        self.flush_writes(sched)?;
        self.reap();
        Ok(done)
    }

    fn accept_new(&mut self) -> Result<()> {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if self.conns.len() >= MAX_CONNS {
                        // Dropping the stream closes it; the client sees a
                        // reset rather than a hung connection.
                        continue;
                    }
                    stream.set_nonblocking(true)?;
                    stream.set_nodelay(true).ok();
                    self.conns.push(Conn {
                        stream,
                        rbuf: Vec::new(),
                        wbuf: Vec::new(),
                        state: ConnState::Reading,
                    });
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            }
        }
    }

    fn read_requests<E: DecodeEngine>(&mut self, sched: &mut Scheduler<E>) -> Result<()> {
        for i in 0..self.conns.len() {
            if self.conns[i].state != ConnState::Reading {
                continue;
            }
            let parsed = {
                let c = &mut self.conns[i];
                let closed = read_available(&mut c.stream, &mut c.rbuf);
                if c.rbuf.len() > MAX_REQUEST_BYTES {
                    c.wbuf = simple_response("400 Bad Request", r#"{"error":"request too large"}"#);
                    c.state = ConnState::Closing;
                    continue;
                }
                match parse_request(&c.rbuf) {
                    Err(_) => {
                        c.wbuf = simple_response("400 Bad Request", r#"{"error":"malformed request"}"#);
                        c.state = ConnState::Closing;
                        continue;
                    }
                    Ok(None) => {
                        if closed {
                            // Peer went away before sending a full request.
                            c.state = ConnState::Dead;
                        }
                        continue;
                    }
                    Ok(Some(r)) => r,
                }
            };
            let action = self.route(&parsed, sched);
            let c = &mut self.conns[i];
            c.rbuf.clear();
            match action {
                Action::Respond(bytes) => {
                    c.wbuf = bytes;
                    c.state = ConnState::Closing;
                }
                Action::Stream(id) => {
                    c.wbuf = SSE_HEADER.to_vec();
                    c.state = ConnState::Streaming { id, sent: 0 };
                }
            }
        }
        Ok(())
    }

    fn route<E: DecodeEngine>(&mut self, r: &HttpRequest, sched: &mut Scheduler<E>) -> Action {
        match (r.method.as_str(), r.path.as_str()) {
            ("GET", "/healthz") => Action::Respond(simple_response("200 OK", &health_json(sched))),
            ("POST", "/generate") => self.admit(r, sched),
            _ => Action::Respond(simple_response("404 Not Found", r#"{"error":"not found"}"#)),
        }
    }

    /// Gate (rate limit, then shed watermark), then submit. Ordering
    /// matters: a rate-limited tenant is told so even under light load,
    /// and a shed response never charges the tenant's bucket refund-less
    /// — the bucket is only debited when the request would otherwise be
    /// admitted. (We accept the small asymmetry that a request passing
    /// the bucket but hitting the watermark has spent a token; under
    /// overload that slows the offending tenants first, which is the
    /// point.)
    fn admit<E: DecodeEngine>(&mut self, r: &HttpRequest, sched: &mut Scheduler<E>) -> Action {
        let tenant = r.headers.get("x-tenant").map(String::as_str).unwrap_or("anon");
        if let Some(rate) = self.cfg.rate_per_sec {
            let burst = self.cfg.burst.max(1.0);
            let now = Instant::now();
            let b = self
                .buckets
                .entry(tenant.to_string())
                .or_insert_with(|| TenantBucket { bucket: TokenBucket::new(rate, burst), last: now });
            b.bucket.refill(now.duration_since(b.last).as_secs_f64());
            b.last = now;
            if !b.bucket.try_take(1.0) {
                return Action::Respond(too_many("rate_limited"));
            }
        }
        if sched.queue_depth() >= self.cfg.shed_depth || !sched.has_queue_capacity() {
            return Action::Respond(too_many("overloaded"));
        }
        let req = match build_gen_request(&r.body) {
            Ok(g) => g,
            Err(e) => return Action::Respond(simple_response("400 Bad Request", &error_json(&e))),
        };
        match sched.submit(req) {
            Ok(id) => Action::Stream(id),
            Err(e) => Action::Respond(simple_response("400 Bad Request", &error_json(&e))),
        }
    }

    /// Read every streaming socket; EOF / hard error ⇒ the client is gone
    /// ⇒ cancel its request so the slot and pages free this very poll.
    fn check_disconnects<E: DecodeEngine>(&mut self, sched: &mut Scheduler<E>) -> Result<()> {
        for c in self.conns.iter_mut() {
            let ConnState::Streaming { id, .. } = c.state else { continue };
            let mut scratch = [0u8; 256];
            let gone = loop {
                match c.stream.read(&mut scratch) {
                    Ok(0) => break true,
                    // Mid-stream client chatter is legal; drain and ignore.
                    Ok(_) => continue,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break false,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => break true,
                }
            };
            if gone {
                sched.cancel(id)?;
                c.state = ConnState::Dead;
            }
        }
        Ok(())
    }

    /// Move hook emissions into the owning streams' write buffers. `idx <
    /// sent` means the scheduler is replaying a restarted request's
    /// prefix; the client already has those bytes.
    fn drain_tokens(&mut self) {
        let mut bus = self.bus.borrow_mut();
        for (id, idx, byte) in bus.drain(..) {
            for c in self.conns.iter_mut() {
                if let ConnState::Streaming { id: cid, sent } = &mut c.state {
                    if *cid != id {
                        continue;
                    }
                    if idx >= *sent {
                        debug_assert_eq!(idx, *sent, "token emission out of order");
                        c.wbuf.extend_from_slice(token_event(id, idx, byte).as_bytes());
                        *sent = idx + 1;
                    }
                    break;
                }
            }
        }
    }

    fn deliver_completions(&mut self, done: &[Completion]) {
        for comp in done {
            for c in self.conns.iter_mut() {
                if matches!(c.state, ConnState::Streaming { id, .. } if id == comp.id) {
                    c.wbuf.extend_from_slice(done_event(comp).as_bytes());
                    c.state = ConnState::Closing;
                    break;
                }
            }
        }
    }

    fn flush_writes<E: DecodeEngine>(&mut self, sched: &mut Scheduler<E>) -> Result<()> {
        for c in self.conns.iter_mut() {
            if c.state == ConnState::Dead {
                continue;
            }
            while !c.wbuf.is_empty() {
                match c.stream.write(&c.wbuf) {
                    Ok(0) => {
                        if let ConnState::Streaming { id, .. } = c.state {
                            sched.cancel(id)?;
                        }
                        c.state = ConnState::Dead;
                        break;
                    }
                    Ok(n) => {
                        c.wbuf.drain(..n);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        if let ConnState::Streaming { id, .. } = c.state {
                            sched.cancel(id)?;
                        }
                        c.state = ConnState::Dead;
                        break;
                    }
                }
            }
        }
        Ok(())
    }

    /// Drop dead sockets and fully-flushed `Closing` ones (dropping the
    /// `TcpStream` sends FIN).
    fn reap(&mut self) {
        self.conns.retain(|c| match c.state {
            ConnState::Dead => false,
            ConnState::Closing => !c.wbuf.is_empty(),
            _ => true,
        });
    }
}

/// Non-blocking read of everything currently available. Returns `true`
/// if the peer closed (EOF or hard error).
fn read_available(stream: &mut TcpStream, buf: &mut Vec<u8>) -> bool {
    let mut tmp = [0u8; 4096];
    loop {
        match stream.read(&mut tmp) {
            Ok(0) => return true,
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => return false,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return true,
        }
    }
}

pub(crate) struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Keys lowercased; values trimmed.
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

/// Incremental HTTP/1.1 request parse. `Ok(None)` = need more bytes;
/// `Err` = malformed beyond repair (answer 400).
pub(crate) fn parse_request(buf: &[u8]) -> Result<Option<HttpRequest>> {
    let Some(head_end) = find_subslice(buf, b"\r\n\r\n") else {
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..head_end])?;
    let mut lines = head.split("\r\n");
    let req_line = lines.next().unwrap_or("");
    let mut parts = req_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line {req_line:?}");
    }
    let mut headers = HashMap::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let clen: usize = headers
        .get("content-length")
        .map(|v| v.parse::<usize>())
        .transpose()?
        .unwrap_or(0);
    if clen > MAX_REQUEST_BYTES {
        bail!("content-length {clen} exceeds limit");
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + clen {
        return Ok(None);
    }
    Ok(Some(HttpRequest {
        method,
        path,
        headers,
        body: buf[body_start..body_start + clen].to_vec(),
    }))
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// JSON body → [`GenRequest`]. Field set documented on the module.
fn build_gen_request(body: &[u8]) -> Result<GenRequest> {
    let j = Json::parse(std::str::from_utf8(body)?)?;
    let prompt = j
        .req("prompt")?
        .as_str()
        .ok_or_else(|| anyhow!("prompt must be a string"))?;
    if prompt.is_empty() {
        bail!("prompt must be non-empty");
    }
    let max_new = j.get("max_new_tokens").and_then(Json::as_usize).unwrap_or(32);
    let seed = j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let name = j.get("sampler").and_then(Json::as_str).unwrap_or("greedy");
    let temperature = j.get("temperature").and_then(Json::as_f64).unwrap_or(1.0) as f32;
    let top_k = j.get("top_k").and_then(Json::as_usize).unwrap_or(8);
    let top_p = j.get("top_p").and_then(Json::as_f64).unwrap_or(0.9) as f32;
    let sampler = Sampler::parse(name, temperature, top_k, top_p)?;
    let mut g = GenRequest::sampled(prompt.as_bytes(), max_new, sampler, seed);
    if let Some(ms) = j.get("deadline_ms").and_then(Json::as_f64) {
        g = g.with_deadline_ms(ms);
    }
    Ok(g)
}

const SSE_HEADER: &[u8] = b"HTTP/1.1 200 OK\r\n\
content-type: text/event-stream\r\n\
cache-control: no-cache\r\n\
connection: close\r\n\r\n";

fn token_event(id: u64, idx: usize, byte: u8) -> String {
    format!("event: token\ndata: {{\"id\":{id},\"idx\":{idx},\"byte\":{byte}}}\n\n")
}

fn done_event(c: &Completion) -> String {
    let j = json::obj(vec![
        ("id", json::num(c.id as f64)),
        ("reason", json::s(&format!("{:?}", c.reason))),
        ("n_tokens", json::num(c.completion.len() as f64)),
        (
            "completion",
            json::arr(c.completion.iter().map(|&b| json::num(b as f64)).collect()),
        ),
        ("ttft_ms", c.ttft_ms.map(json::num).unwrap_or(Json::Null)),
        ("latency_ms", json::num(c.latency_ms)),
    ]);
    format!("event: done\ndata: {}\n\n", j.to_string())
}

fn simple_response(status: &str, body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn too_many(why: &str) -> Vec<u8> {
    let body = format!("{{\"error\":\"{why}\"}}");
    format!(
        "HTTP/1.1 429 Too Many Requests\r\ncontent-type: application/json\r\nretry-after: 1\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn error_json(e: &anyhow::Error) -> String {
    json::obj(vec![("error", json::s(&e.to_string()))]).to_string()
}

fn health_json<E: DecodeEngine>(sched: &Scheduler<E>) -> String {
    json::obj(vec![
        ("status", json::s("ok")),
        ("queue_depth", json::num(sched.queue_depth() as f64)),
        ("in_flight", json::num(sched.in_flight() as f64)),
        ("slots", json::num(sched.slot_capacity() as f64)),
    ])
    .to_string()
}

// ---------------------------------------------------------------------------
// Blocking client helper — used by the loopback tests here, the open-loop
// load generator, and anything else that wants a one-shot SSE request from
// another thread.
// ---------------------------------------------------------------------------

/// Result of one blocking `/generate` round-trip.
#[derive(Debug)]
pub struct StreamOutcome {
    /// HTTP status (200 for a stream, 429 for shed/rate-limit, ...).
    pub status: u16,
    /// Completion bytes reassembled from `token` events (replays deduped).
    pub bytes: Vec<u8>,
    /// Parsed `done` payload, if the stream finished cleanly.
    pub done: Option<Json>,
    /// Arrival time of each token event, ms since the call started.
    pub token_at_ms: Vec<f64>,
}

/// Blocking one-shot request against a front at `addr`: writes the POST,
/// reads to EOF (bounded by `timeout` per read), parses the SSE stream.
/// Safe to call from worker threads — only the socket lives here.
pub fn blocking_request(
    addr: SocketAddr,
    body: &str,
    tenant: &str,
    timeout: Duration,
) -> Result<StreamOutcome> {
    let t0 = Instant::now();
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(timeout))?;
    s.set_nodelay(true).ok();
    let req = format!(
        "POST /generate HTTP/1.1\r\nhost: localhost\r\nx-tenant: {tenant}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    let mut tmp = [0u8; 4096];
    let mut token_at_ms = Vec::new();
    let mut events_seen = 0usize;
    loop {
        match s.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => {
                raw.extend_from_slice(&tmp[..n]);
                let now_events = count_token_events(&raw);
                let now_ms = t0.elapsed().as_secs_f64() * 1e3;
                for _ in events_seen..now_events {
                    token_at_ms.push(now_ms);
                }
                events_seen = now_events;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    let mut out = parse_sse_response(&raw)?;
    out.token_at_ms = token_at_ms;
    Ok(out)
}

fn count_token_events(raw: &[u8]) -> usize {
    let Some(body_start) = find_subslice(raw, b"\r\n\r\n") else {
        return 0;
    };
    let body = &raw[body_start + 4..];
    // Count only *complete* events (terminated by the blank line).
    String::from_utf8_lossy(body)
        .split("\n\n")
        .filter(|ev| ev.lines().any(|l| l == "event: token"))
        .count()
}

/// Parse a full captured response (status line + SSE body) into a
/// [`StreamOutcome`] (without timing — `token_at_ms` is left empty).
pub fn parse_sse_response(raw: &[u8]) -> Result<StreamOutcome> {
    let Some(head_end) = find_subslice(raw, b"\r\n\r\n") else {
        bail!("truncated response ({} bytes, no header terminator)", raw.len());
    };
    let head = String::from_utf8_lossy(&raw[..head_end]).to_string();
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad status line in {head:?}"))?;
    let mut out = StreamOutcome { status, bytes: Vec::new(), done: None, token_at_ms: Vec::new() };
    if status != 200 {
        return Ok(out);
    }
    let body = String::from_utf8_lossy(&raw[head_end + 4..]).to_string();
    for ev in body.split("\n\n") {
        let mut name = "";
        let mut data = "";
        for line in ev.lines() {
            if let Some(v) = line.strip_prefix("event: ") {
                name = v;
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = v;
            }
        }
        match name {
            "token" => {
                let j = Json::parse(data)?;
                let idx = j.req("idx")?.as_usize().ok_or_else(|| anyhow!("bad idx"))?;
                let byte = j.req("byte")?.as_usize().ok_or_else(|| anyhow!("bad byte"))? as u8;
                if idx == out.bytes.len() {
                    out.bytes.push(byte);
                }
                // idx < len is a server-side replay that slipped through;
                // idx > len cannot happen (server writes in order).
            }
            "done" => out.done = Some(Json::parse(data)?),
            _ => {}
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::MockEngine;

    fn front(cfg: HttpFrontConfig) -> HttpFront {
        HttpFront::bind("127.0.0.1:0", cfg).unwrap()
    }

    fn gen_body(prompt: &str, max_new: usize, seed: u64) -> String {
        format!(
            "{{\"prompt\":\"{prompt}\",\"max_new_tokens\":{max_new},\"seed\":{seed},\
             \"sampler\":\"top-k\",\"top_k\":4,\"temperature\":0.7}}"
        )
    }

    /// Same-thread test client: blocking socket with a short read timeout
    /// so the test loop can interleave reads with `front.poll`.
    struct TestClient {
        stream: TcpStream,
        raw: Vec<u8>,
        eof: bool,
    }

    impl TestClient {
        fn post(addr: SocketAddr, body: &str, tenant: &str) -> Self {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_millis(1))).unwrap();
            stream.set_nodelay(true).ok();
            let req = format!(
                "POST /generate HTTP/1.1\r\nhost: t\r\nx-tenant: {tenant}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
                body.len()
            );
            stream.write_all(req.as_bytes()).unwrap();
            Self { stream, raw: Vec::new(), eof: false }
        }

        fn get(addr: SocketAddr, path: &str) -> Self {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_millis(1))).unwrap();
            let req = format!("GET {path} HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n");
            stream.write_all(req.as_bytes()).unwrap();
            Self { stream, raw: Vec::new(), eof: false }
        }

        /// Pull whatever is available; returns true once EOF is reached.
        fn pump(&mut self) -> bool {
            if self.eof {
                return true;
            }
            let mut tmp = [0u8; 4096];
            loop {
                match self.stream.read(&mut tmp) {
                    Ok(0) => {
                        self.eof = true;
                        return true;
                    }
                    Ok(n) => self.raw.extend_from_slice(&tmp[..n]),
                    Err(e)
                        if e.kind() == ErrorKind::WouldBlock
                            || e.kind() == ErrorKind::TimedOut =>
                    {
                        return false;
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        self.eof = true;
                        return true;
                    }
                }
            }
        }

        fn outcome(&self) -> StreamOutcome {
            parse_sse_response(&self.raw).unwrap()
        }

        fn token_events(&self) -> usize {
            count_token_events(&self.raw)
        }
    }

    fn drive<E: DecodeEngine>(
        front: &mut HttpFront,
        sched: &mut Scheduler<E>,
        clients: &mut [&mut TestClient],
        until_all_eof: bool,
    ) {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            front.poll(sched).unwrap();
            let mut all_eof = true;
            for c in clients.iter_mut() {
                if !c.pump() {
                    all_eof = false;
                }
            }
            if until_all_eof && all_eof {
                return;
            }
            if !until_all_eof && sched.is_idle() && front.conn_count() == 0 {
                return;
            }
            assert!(Instant::now() < deadline, "test front loop timed out");
        }
    }

    #[test]
    fn token_bucket_refill_is_deterministic() {
        let mut b = TokenBucket::new(2.0, 4.0);
        assert_eq!(b.available(), 4.0);
        assert!(b.try_take(4.0));
        assert!(!b.try_take(1.0), "empty bucket must refuse");
        assert_eq!(b.available(), 0.0);
        b.refill(0.5); // 0.5s * 2/s = 1 token, exactly
        assert_eq!(b.available(), 1.0);
        assert!(b.try_take(1.0));
        // Same elapsed input always credits the same amount.
        let mut b2 = TokenBucket::new(2.0, 4.0);
        b2.try_take(4.0);
        b2.refill(0.25);
        b2.refill(0.25);
        assert_eq!(b2.available(), 1.0, "split refills equal one combined refill");
    }

    #[test]
    fn token_bucket_caps_burst() {
        let mut b = TokenBucket::new(100.0, 3.0);
        b.refill(1e6); // eons of credit...
        assert_eq!(b.available(), 3.0, "...still capped at burst capacity");
        assert!(b.try_take(3.0));
        assert!(!b.try_take(0.5));
    }

    #[test]
    fn request_parse_is_incremental() {
        let full = b"POST /generate HTTP/1.1\r\ncontent-length: 4\r\nx-tenant: t9\r\n\r\nbody";
        for cut in 0..full.len() {
            assert!(
                parse_request(&full[..cut]).unwrap().is_none(),
                "prefix of {cut} bytes must be incomplete"
            );
        }
        let r = parse_request(full).unwrap().unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/generate");
        assert_eq!(r.headers.get("x-tenant").unwrap(), "t9");
        assert_eq!(r.body, b"body");
        assert!(parse_request(b"\r\n\r\n").is_err(), "empty request line is malformed");
    }

    /// Acceptance criterion: N concurrent SSE clients stream completions
    /// byte-identical to the same requests run directly through the
    /// scheduler (generation is deterministic per (prompt, sampler,
    /// seed), independent of batching or arrival order).
    #[test]
    fn loopback_concurrent_streams_match_direct_run() {
        let prompts = ["alpha alpha", "bravo bravo", "charlie charlie"];
        // Direct baseline on an identical fresh scheduler.
        let mut direct = Scheduler::new(MockEngine::new(2, 64, 64), 8).unwrap();
        let baseline = direct
            .serve_all(prompts.iter().enumerate().map(|(i, p)| {
                GenRequest::sampled(p.as_bytes(), 12, Sampler::top_k(4, 0.7), i as u64)
            }))
            .unwrap();

        let mut sched = Scheduler::new(MockEngine::new(2, 64, 64), 8).unwrap();
        let mut f = front(HttpFrontConfig::default());
        f.install_token_hook(&mut sched);
        let addr = f.local_addr().unwrap();
        let mut clients: Vec<TestClient> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| TestClient::post(addr, &gen_body(p, 12, i as u64), "t"))
            .collect();
        {
            let mut refs: Vec<&mut TestClient> = clients.iter_mut().collect();
            drive(&mut f, &mut sched, &mut refs, true);
        }

        for (i, c) in clients.iter().enumerate() {
            let out = c.outcome();
            assert_eq!(out.status, 200);
            let want = baseline
                .iter()
                .find(|b| b.prompt == prompts[i].as_bytes())
                .expect("baseline completion for prompt");
            assert_eq!(out.bytes, want.completion, "stream {i} diverged from direct run");
            let done = out.done.expect("stream must end with a done event");
            assert_eq!(done.req("n_tokens").unwrap().as_usize(), Some(want.completion.len()));
            // The done event's byte array must match the streamed tokens.
            let arr: Vec<u8> = done
                .req("completion")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap() as u8)
                .collect();
            assert_eq!(arr, out.bytes);
        }
        assert!(sched.is_idle());
        sched.check_invariants().unwrap();
    }

    /// Acceptance criterion: a mid-stream disconnect cancels within one
    /// poll — the slot frees, pages return, and the queued next request
    /// admits and completes.
    #[test]
    fn mid_stream_disconnect_cancels_and_next_request_admits() {
        let mut sched = Scheduler::new(MockEngine::new(1, 128, 64), 8).unwrap();
        let mut f = front(HttpFrontConfig::default());
        f.install_token_hook(&mut sched);
        let addr = f.local_addr().unwrap();

        // A: long-running stream occupying the only slot.
        let mut a = TestClient::post(addr, &gen_body("long running victim", 64, 1), "t");
        // B: queued behind A.
        let mut b = TestClient::post(addr, &gen_body("queued survivor", 4, 2), "t");
        let deadline = Instant::now() + Duration::from_secs(10);
        while a.token_events() < 2 {
            front_poll(&mut f, &mut sched);
            a.pump();
            b.pump();
            assert!(Instant::now() < deadline, "never saw A's first tokens");
        }
        assert_eq!(sched.in_flight(), 1);
        assert_eq!(sched.queue_depth(), 1);

        drop(a); // client vanishes mid-stream (FIN)
        // One poll: the front must observe the FIN, cancel A *before* the
        // step, and the step then admits B into the freed slot.
        front_poll(&mut f, &mut sched);
        assert_eq!(sched.queue_depth(), 0, "B must admit in the poll that cancels A");
        assert_eq!(sched.in_flight(), 1, "only B remains");
        sched.check_invariants().unwrap();

        {
            let mut refs: Vec<&mut TestClient> = vec![&mut b];
            drive(&mut f, &mut sched, &mut refs, true);
        }
        let out = b.outcome();
        assert_eq!(out.status, 200);
        assert_eq!(out.bytes.len(), 4, "B runs to its full budget");
        assert!(sched.is_idle());
    }

    fn front_poll(f: &mut HttpFront, sched: &mut Scheduler<MockEngine>) {
        f.poll(sched).unwrap();
    }

    /// Acceptance criterion: overload returns 429 at the shed watermark —
    /// the queue never grows past `shed_depth`.
    #[test]
    fn overload_sheds_with_429_at_watermark() {
        let mut sched = Scheduler::new(MockEngine::new(1, 256, 64), 8).unwrap();
        let mut f = front(HttpFrontConfig { shed_depth: 1, ..HttpFrontConfig::default() });
        f.install_token_hook(&mut sched);
        let addr = f.local_addr().unwrap();

        let mut a = TestClient::post(addr, &gen_body("occupies the slot", 128, 1), "t");
        let deadline = Instant::now() + Duration::from_secs(10);
        while a.token_events() < 1 {
            front_poll(&mut f, &mut sched);
            a.pump();
            assert!(Instant::now() < deadline);
        }
        let mut b = TestClient::post(addr, &gen_body("fills the queue", 4, 2), "t");
        // Let the front admit B (queue depth 1 = the watermark).
        while sched.queue_depth() < 1 {
            front_poll(&mut f, &mut sched);
            a.pump();
            b.pump();
            assert!(Instant::now() < deadline);
        }
        let mut c = TestClient::post(addr, &gen_body("must be shed", 4, 3), "t");
        while !c.pump() {
            front_poll(&mut f, &mut sched);
            a.pump();
            b.pump();
            assert!(Instant::now() < deadline, "shed response never arrived");
        }
        assert_eq!(c.outcome().status, 429, "at the watermark the front must shed");
        assert_eq!(sched.queue_depth(), 1, "queue never grows past shed_depth");

        drop(a); // free the slot so B drains
        {
            let mut refs: Vec<&mut TestClient> = vec![&mut b];
            drive(&mut f, &mut sched, &mut refs, true);
        }
        assert_eq!(b.outcome().status, 200);
        assert_eq!(b.outcome().bytes.len(), 4);
    }

    #[test]
    fn rate_limit_is_per_tenant_key() {
        let mut sched = Scheduler::new(MockEngine::new(2, 64, 64), 8).unwrap();
        // Effectively no refill during the test; burst of exactly 1.
        let mut f = front(HttpFrontConfig {
            rate_per_sec: Some(1e-9),
            burst: 1.0,
            shed_depth: 64,
        });
        f.install_token_hook(&mut sched);
        let addr = f.local_addr().unwrap();

        let mut t1a = TestClient::post(addr, &gen_body("first from t1", 3, 1), "t1");
        let mut t1b = TestClient::post(addr, &gen_body("second from t1", 3, 2), "t1");
        let mut t2 = TestClient::post(addr, &gen_body("first from t2", 3, 3), "t2");
        {
            let mut refs: Vec<&mut TestClient> = vec![&mut t1a, &mut t1b, &mut t2];
            drive(&mut f, &mut sched, &mut refs, true);
        }

        let (o1a, o1b, o2) = (t1a.outcome(), t1b.outcome(), t2.outcome());
        // t1's burst is 1: exactly one of its two requests streamed, the
        // other was rate-limited (arrival order at the front decides
        // which — both sockets race through accept).
        let statuses = {
            let mut s = vec![o1a.status, o1b.status];
            s.sort_unstable();
            s
        };
        assert_eq!(statuses, vec![200, 429], "tenant t1 gets one stream + one 429");
        assert_eq!(o2.status, 200, "tenant t2's bucket is independent");
        assert!(sched.is_idle());
    }

    #[test]
    fn healthz_reports_scheduler_state() {
        let mut sched = Scheduler::new(MockEngine::new(2, 64, 64), 8).unwrap();
        let mut f = front(HttpFrontConfig::default());
        let addr = f.local_addr().unwrap();
        let mut h = TestClient::get(addr, "/healthz");
        let deadline = Instant::now() + Duration::from_secs(10);
        while !h.pump() {
            front_poll(&mut f, &mut sched);
            assert!(Instant::now() < deadline);
        }
        let raw = String::from_utf8_lossy(&h.raw).to_string();
        assert!(raw.starts_with("HTTP/1.1 200 OK"), "got {raw:?}");
        let body = &raw[raw.find("\r\n\r\n").unwrap() + 4..];
        let j = Json::parse(body).unwrap();
        assert_eq!(j.req("queue_depth").unwrap().as_usize(), Some(0));
        assert_eq!(j.req("slots").unwrap().as_usize(), Some(2));

        let mut nf = TestClient::get(addr, "/nope");
        while !nf.pump() {
            front_poll(&mut f, &mut sched);
            assert!(Instant::now() < deadline);
        }
        assert!(String::from_utf8_lossy(&nf.raw).starts_with("HTTP/1.1 404"));
    }
}
