//! The serving subsystem: continuous batching over the quantized KV cache.
//!
//! The paper's headline systems claim (Table 6 / Fig. 7) is that 4-bit
//! W/A/KV SpinQuant models are cheap enough to *serve*; this module is the
//! runtime that actually serves them. It promotes and absorbs the old
//! single-request `coordinator::serve` loop into six pieces:
//!
//! * [`engine`] — the [`DecodeEngine`] trait: step a whole *batch* of slots
//!   through one decode iteration, and *prefill* a multi-token prompt chunk
//!   per slot in one call (`prefill_chunk()` tokens; the chunked fallback
//!   runs the decode step in a loop when no prefill graph exists). Engines
//!   that expose a paged KV layout (`kv_block_size()`) additionally take a
//!   per-slot *block table* through `step_paged` / `prefill_paged`.
//!   Implementations: [`PjrtEngine`] (the real thing, over the `decode_*` /
//!   `decode_*_b{N}` / `prefill_*_b{N}_t{T}` and `*_paged_*` AOT artifacts,
//!   KV cache kept as PJRT literals and shared between the decode and
//!   prefill bindings) and [`MockEngine`] (a deterministic in-process model
//!   for scheduler/sampler tests and for benching the scheduler itself
//!   without artifacts; counts decode steps and prefill calls, and in paged
//!   mode stores tokens in real physical pages so table corruption is
//!   caught, not simulated away).
//! * [`blocks`] — [`BlockPool`], the paged KV-cache page allocator. Page
//!   ownership is **refcounted**: `allocate` hands a page out at refcount
//!   1, `retain` lets more block tables (or the prefix index) map it, and
//!   `release` frees only when the last reference drops, under the strict
//!   invariant `free + Σ(refcount > 0) == total` (releases are
//!   batch-atomic; double-frees are loud errors). Plus the
//!   [`blocks::kv_memory_bytes`] formula the serving bench audits its
//!   memory budgets with — physical pages, so shared pages count once, at
//!   any KV storage width (packed payload rounded up per page, plus
//!   per-group scale metadata below 16 bits).
//! * [`prefix`] — [`prefix::PrefixIndex`], the content-addressed prefix
//!   cache: full, immutable prompt pages keyed by a `(parent chain, page
//!   tokens)` hash chain. Donated pages stay resident (the index holds a
//!   reference) until pool pressure evicts them LRU; pages mapped by live
//!   slots are structurally unevictable.
//! * [`slots`] — [`SlotMap`], the slot-based KV-cache bookkeeping:
//!   allocate/free/advance (by one token or a whole prefill chunk) with
//!   per-slot position tracking and strict capacity accounting. In paged
//!   mode ([`SlotMap::paged`]) each slot carries a block table over the
//!   shared [`BlockPool`] instead of assuming a dense `[0, max_seq)` range;
//!   tables grow lazily at page boundaries and positions can never outrun
//!   their pages. With [`SlotMap::with_prefix_cache`],
//!   [`SlotMap::admit_paged`] maps a new request's longest cached prompt
//!   prefix read-only into its table (copy-on-write: the first written
//!   page is always a fresh copy, recomputed through prefill — which is
//!   why the PJRT graphs need no change), and full prompt pages are
//!   donated to the index the moment they fill. Slot reuse needs no cache
//!   zeroing: the decode graphs mask attention to `idx <= pos`, so a
//!   freshly admitted request starting at `pos = 0` can never observe a
//!   previous occupant's stale keys/values.
//! * [`scheduler`] — [`Scheduler`], the continuous-batching loop: an
//!   admission queue with backpressure, batched prompt prefill (a newly
//!   admitted request reaches its first token in `ceil(len/T)` engine
//!   calls, then joins the per-token decode batch; `T == 1` keeps the old
//!   interleaved path), mid-flight join (a request enters the batch on the
//!   step after a slot frees, without draining in-flight requests) and
//!   evict ([`Scheduler::cancel`] frees a slot immediately), per-request
//!   token budgets, and completion accounting. Over a paged engine it
//!   admits by free-page *token budget* (`ceil((len + max_new)/bs)` pages
//!   reservable) instead of slot count, grows tables lazily during decode,
//!   and evicts the youngest request back to the queue front when the pool
//!   runs dry — so concurrency is bounded by tokens in flight, not by
//!   `slots x max_seq` worst-case reservations. With
//!   [`Scheduler::with_prefix_cache`] the watermark charges only a
//!   request's *non-shared* page demand and prefill starts at the first
//!   uncached position, so N users repeating one system prompt pay for it
//!   once — with bit-identical output (sharing removes recomputation,
//!   never changes content). With [`Scheduler::with_step_budget`] the
//!   drain-prefill-then-decode loop becomes a decode-priority **step
//!   composer**: every iteration runs the full decode batch first, then
//!   fills what remains of the per-step token budget with prompt chunks
//!   from warming slots (splitting prompts at arbitrary boundaries over
//!   the ragged `n_valid` prefill graphs, with a starvation guard so
//!   prefill always progresses) — so one long prompt can no longer stall
//!   every in-flight decode for a whole `ceil(len/T)`-call burst. The
//!   legacy threaded FIFO front ([`Server`]) also lives here. The
//!   scheduler's bookkeeping is held to a pure reference simulator by
//!   randomized trace tests — see [`crate::testing::sim`].
//! * [`sampling`] — greedy / temperature / top-k / top-p samplers, seeded
//!   via [`crate::util::prng`] so generations are exactly reproducible;
//!   candidate selection is partial (`select_nth_unstable_by`), never a
//!   full-vocabulary sort per step.
//! * [`metrics`] — time-to-first-token (measured from enqueue, so queue
//!   wait is visible, and split into queue wait vs prefill spread so a
//!   prompt scattered across many budgeted steps can't masquerade as
//!   queue time), prefill-call latency (kept separate from per-token
//!   decode latency), per-token latency percentiles, the decode-stall
//!   histogram + inter-token latency + prefill-share gauge the step
//!   composer is tuned by, tokens/sec, queue depth, eviction counts,
//!   prefix-cache reuse (`tokens_reused`, hit rate); exportable as JSON
//!   through [`crate::report`]. Aggregates only — per-request attribution
//!   lives in the trace layer below.
//! * [`trace`] — the flight recorder: a bounded ring buffer of typed,
//!   step-indexed [`TraceEvent`]s the whole stack emits into (request
//!   lifecycle: `Enqueued` → `Admitted`/`PrefixHit` → `PrefillChunk`* →
//!   `TokenDecoded`* → `Evicted`/`Completed`; resource plane:
//!   `PageAllocated`/`PageRetained`/`PageReleased`, `PrefixDonated`;
//!   per-step: `StepComposed`, `Counters`). Enabled with
//!   [`Scheduler::with_trace`] (`serve --trace out.json --trace-buffer N`);
//!   off, the sink is an enum unit variant — one branch per emission site,
//!   no buffer, no allocation. [`trace::fold_timelines`] reconstructs
//!   per-request lifecycle spans, [`trace::verify_against_metrics`]
//!   cross-checks them against [`ServingMetrics`] (TTFT = queue + spread,
//!   stall histogram identical), and [`trace::chrome_trace`] exports a
//!   Chrome trace-event / Perfetto JSON view (one track per slot, counter
//!   tracks for queue depth / free pages / in-flight / token mix). The
//!   oracle in [`crate::testing::sim`] emits the same event stream from
//!   its bookkeeping model, and the pinned-seed suites require exact
//!   sequence equality (modulo timestamps) — scheduler decisions are a
//!   CI-checked observable, not just telemetry.
//!
//! Quantized KV page storage (`serve --kv-bits {4,8,16}`): the L2 paged
//! graphs fake-quant K/V *before* scattering to physical pages, so a page
//! holds quantize→dequantize round-tripped values on a symmetric per-group
//! grid — the page is the storage format, not a staging buffer. `kv_bits`
//! rides the runtime qcfg vector (one lowered artifact covers every width;
//! 16 is exact pass-through, bit-identical to the pre-quantization paged
//! path), [`DecodeEngine::kv_bits`] reports the width the engine stores
//! at, and [`blocks::kv_memory_bytes`] prices the packed pages — at an
//! equal page-byte budget, int4 pages hold ~3.6x the tokens of fp16
//! (scale metadata included), which the `kv_quant` bench section measures
//! as in-flight concurrency together with greedy-drift quality checks.
//! The fp decode variant has no qcfg input, so `--kv-bits` there falls
//! back to full-precision pages with a loud warning rather than silently
//! misreporting capacity.
//!
//! # Speculative decoding (`serve --spec-k K --spec-draft {ngram,engine}`)
//!
//! [`Scheduler::with_speculation`] turns the per-token decode batch into a
//! draft/verify loop: each running slot proposes up to K tokens from a
//! cheap draft source — [`scheduler::SpecDraft::NGram`] (prompt lookup:
//! the longest recurring n-gram's continuation out of the slot's own
//! history, zero extra compute) or [`scheduler::SpecDraft::Engine`] (a
//! second, low-fidelity [`DecodeEngine`] — e.g. a lower-bit rung of the
//! same quantization ladder — kept in lockstep with the target's
//! committed history) — and the target engine scores all K+1 positions in
//! **one** ragged verify call ([`DecodeEngine::verify`] /
//! [`DecodeEngine::verify_paged`]). Greedy acceptance keeps the longest
//! agreeing prefix plus the free correction token sampled from the first
//! disagreeing row; rejected tokens roll back through
//! [`DecodeEngine::rewind`] + [`SlotMap::rewind_by`] — positions *and*
//! paged state, so pages grown for the window are released at the
//! committed frontier, and speculative advances never donate to the
//! prefix index, so a rejected token can never become cache-resident.
//! Acceptance consumes the sampler's PRNG draws exactly as sequential
//! decoding would, so output is **byte-identical** to the non-speculative
//! run at any K, with any sampler, any draft source: speculation changes
//! call counts (`verify_calls`, `accept_rate`, tokens-per-engine-call —
//! the `spec_decode` bench section), never bytes. `--spec-k 0` (or
//! omitting the flag) leaves every pre-existing path bit-untouched.
//!
//! # Network front & overload policy (`serve --http PORT`)
//!
//! [`http::HttpFront`] is the network edge: a dependency-free HTTP/1.1 +
//! SSE front built as a non-blocking `TcpListener` poll loop *around* the
//! scheduler on its owning thread (PJRT handles are not `Send`, so the
//! scheduler never migrates; sockets multiplex to it). `POST /generate`
//! opens a `text/event-stream` fed by the scheduler's per-token hook
//! ([`Scheduler::set_token_hook`]):
//!
//! * `event: token`, `data: {"id":I,"idx":N,"byte":B}` — one event per
//!   generated byte; `idx` is the absolute completion offset, so
//!   eviction-restart replays dedupe against the stream's high-water mark
//!   and a client never sees a byte twice.
//! * `event: done`, `data: {completion bytes, reason, ttft_ms,
//!   latency_ms}` — terminal; the connection then closes.
//!
//! Overload never queues unboundedly: admission is gated by a per-tenant
//! token bucket (tenant = `x-tenant` header, default `anon`; `--rate-limit
//! N` req/s sustained with a configurable burst) and by a queue-depth
//! watermark (`--shed-depth D`) — either trips a complete, parseable
//! `429` response, so the scheduler queue can never grow past the
//! watermark. A client disconnect propagates to [`Scheduler::cancel`]
//! *before* the next step runs: the slot and its pages free within one
//! poll and in-flight pages are never donated to the prefix index
//! (cancel tears down through the donation-free `release` path).
//! `GET /healthz` reports queue depth / in-flight / slot capacity.
//!
//! [`loadgen`] is the matching measurement layer: a seeded *open-loop*
//! Poisson load generator (`spinquant loadgen`, also the bench's
//! `serving_load` sweep) that launches arrivals on schedule regardless of
//! completions — so backlog builds exactly as under real load and TTFT is
//! charged from the scheduled arrival instant (no coordinated omission) —
//! with mixed prompt/output lengths and 1/(rank+1) tenant skew, driving
//! the real front over loopback and reporting goodput, TTFT p50/p99 and
//! inter-token p99 per offered-RPS point.
//!
//! # Failure model & recovery
//!
//! The step loop is an **error kernel**: every engine-touching path in
//! [`Scheduler::step`] is failure-atomic, so a failed call leaves the
//! bookkeeping exactly where it was — no slot half-advanced, no page
//! leaked, and the pool invariant `free + Σ(refcount > 0) == total`
//! intact (auditable any time via [`Scheduler::check_invariants`]; the
//! chaos suites run it after *every* step). Engine failures are
//! classified by [`ServeError`]:
//!
//! * [`ServeError::Slot`] — one request blamed. Its slot keeps its KV
//!   state but sits out `1, 2, 4, ... (≤ 64)` steps of deterministic
//!   backoff (counted in scheduler *steps*, never wall clock, so the sim
//!   oracle replays recovery exactly), then rejoins the batch; after
//!   `--retry-budget` individual faults the request is **quarantined** —
//!   completed with [`FinishReason::Quarantined`] and whatever bytes it
//!   had generated.
//! * [`ServeError::Transient`] — step-wide. The whole loop pauses for the
//!   backoff; a streak of `--retry-budget` consecutive step-wide faults
//!   evicts the call's participants to the queue *front* for a warm
//!   restart (their fault counts survive the trip through the queue).
//! * [`ServeError::Fatal`] — and any non-[`ServeError`] error, e.g. a
//!   PJRT arity mismatch — propagates out of `step()` unretried; the
//!   legacy threaded [`Server`] surfaces it to every pending and
//!   subsequent caller instead of hanging.
//!
//! Requests may also carry a [`Deadline`] (`serve --deadline-ms`):
//! expired requests are shed at admission and mid-flight with
//! [`FinishReason::DeadlineExpired`] before any engine work is spent on
//! them. Recovery *decisions* are observables: `FaultInjected`,
//! `RetryScheduled`, `SlotRecovered`, `RequestFailed` and
//! `DeadlineExpired` trace events plus eight [`ServingMetrics`] counters
//! are modeled by the sim oracle and trace-equivalence-checked in CI
//! against the seeded [`FaultInjector`] (`serve --fault-rate/--fault-seed`)
//! at fault rates {0, 0.01, 0.05}, with surviving requests required to be
//! byte-identical to the fault-free run.

pub mod blocks;
pub mod engine;
pub mod http;
pub mod loadgen;
pub mod metrics;
pub mod prefix;
pub mod sampling;
pub mod scheduler;
pub mod slots;
pub mod trace;

pub use blocks::BlockPool;
pub use engine::{
    DecodeEngine, DecodeVariant, FaultInjector, GenerationSession, MockEngine, PjrtEngine,
    ServeError,
};
pub use http::{HttpFront, HttpFrontConfig, TokenBucket};
pub use loadgen::{run_open_loop, LoadGenConfig, LoadReport};
pub use metrics::ServingMetrics;
pub use sampling::{argmax, Sampler, SamplerKind};
pub use scheduler::{
    Completion, Deadline, GenRequest, Request, Response, Scheduler, Server, SpecDraft,
    DEFAULT_RETRY_BUDGET,
};
pub use slots::{SlotMap, SlotPhase};
pub use trace::{
    chrome_trace, fold_timelines, verify_against_metrics, EvictReason, FinishReason, Timeline,
    TraceEvent, TraceRecord, TraceRing, TraceSink,
};
