//! Wall-clock timing helpers used by the serving loop, the latency tables
//! (paper Table 6 / Fig. 7), and the micro-bench harness.

use std::time::Instant;

/// Collects duration samples and reports robust statistics.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    us: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, micros: f64) {
        self.us.push(micros);
    }

    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.push(t0.elapsed().as_secs_f64() * 1e6);
        out
    }

    pub fn len(&self) -> usize {
        self.us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.us.is_empty()
    }

    pub fn mean_us(&self) -> f64 {
        if self.us.is_empty() {
            return 0.0;
        }
        self.us.iter().sum::<f64>() / self.us.len() as f64
    }

    pub fn percentile_us(&self, p: f64) -> f64 {
        self.percentiles_us(&[p])[0]
    }

    /// Batched percentiles: sort once, read many. Nearest-rank on the same
    /// index formula the single-percentile path always used, so the results
    /// are bit-identical — but `ServingMetrics::to_json` reads ~10
    /// percentiles of the same (growing) sample sets, and this does one
    /// clone-and-sort for all of them instead of one per call.
    ///
    /// `p` outside [0, 100] is a caller bug (the raw index formula would
    /// read out of bounds and panic); it is clamped to the valid range so
    /// release report code degrades to min/max instead of crashing, and
    /// debug builds assert loudly.
    pub fn percentiles_us(&self, ps: &[f64]) -> Vec<f64> {
        if self.us.is_empty() {
            return vec![0.0; ps.len()];
        }
        let mut v = self.us.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.iter()
            .map(|&p| {
                debug_assert!(
                    (0.0..=100.0).contains(&p),
                    "percentile {p} outside [0, 100]"
                );
                let p = p.clamp(0.0, 100.0);
                v[((v.len() - 1) as f64 * p / 100.0).round() as usize]
            })
            .collect()
    }

    pub fn median_us(&self) -> f64 {
        self.percentile_us(50.0)
    }

    /// Smallest sample, or 0.0 on an empty set — like every other accessor
    /// here, so JSON export can never emit `inf`.
    pub fn min_us(&self) -> f64 {
        if self.us.is_empty() {
            return 0.0;
        }
        self.us.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Raw samples in insertion order (the trace verifier and the histogram
    /// export consume these).
    pub fn values(&self) -> &[f64] {
        &self.us
    }

    pub fn stddev_us(&self) -> f64 {
        if self.us.len() < 2 {
            return 0.0;
        }
        let m = self.mean_us();
        let var = self.us.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.us.len() - 1) as f64;
        var.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats() {
        let mut s = Samples::new();
        for v in [1.0, 2.0, 3.0, 4.0, 100.0] {
            s.push(v);
        }
        assert_eq!(s.len(), 5);
        assert!((s.mean_us() - 22.0).abs() < 1e-9);
        assert_eq!(s.median_us(), 3.0);
        assert_eq!(s.min_us(), 1.0);
        assert!(s.percentile_us(100.0) == 100.0);
    }

    #[test]
    fn times_closure() {
        let mut s = Samples::new();
        let out = s.time(|| 41 + 1);
        assert_eq!(out, 42);
        assert_eq!(s.len(), 1);
        assert!(s.mean_us() >= 0.0);
    }

    #[test]
    fn empty_set_accessors_are_zero() {
        let s = Samples::new();
        assert_eq!(s.mean_us(), 0.0);
        assert_eq!(s.min_us(), 0.0, "min over an empty set must not be inf");
        assert_eq!(s.stddev_us(), 0.0);
        assert_eq!(s.percentile_us(99.0), 0.0);
        assert_eq!(s.percentiles_us(&[50.0, 95.0, 99.0]), vec![0.0, 0.0, 0.0]);
        assert!(s.values().is_empty());
    }

    /// `p > 100` used to index `v[(len-1) * p / 100]` out of bounds and
    /// panic unconditionally — on a public API the load-harness report code
    /// calls with computed percentiles. Now: debug builds assert on the
    /// misuse; release builds clamp to the max sample.
    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "outside [0, 100]"))]
    fn percentile_above_100_clamps_to_max() {
        let mut s = Samples::new();
        for v in [1.0, 2.0, 3.0] {
            s.push(v);
        }
        assert_eq!(s.percentile_us(150.0), 3.0);
        assert_eq!(s.percentiles_us(&[101.0, 1e9]), vec![3.0, 3.0]);
    }

    /// Negative percentiles are the mirror-image misuse: the rounded index
    /// would be negative (a wrapping cast) — clamp to the min sample.
    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "outside [0, 100]"))]
    fn percentile_below_0_clamps_to_min() {
        let mut s = Samples::new();
        for v in [1.0, 2.0, 3.0] {
            s.push(v);
        }
        assert_eq!(s.percentile_us(-5.0), 1.0);
    }

    #[test]
    fn percentiles_batched_bit_identical_to_per_call() {
        use crate::testing::prop::forall;
        forall(77, 200, |g| {
            let n = g.int(0, 40);
            let mut s = Samples::new();
            for _ in 0..n {
                s.push(g.f32(0.0, 1000.0) as f64);
            }
            let ps: Vec<f64> =
                (0..g.int(1, 8)).map(|_| g.f32(0.0, 100.0) as f64).collect();
            let batched = s.percentiles_us(&ps);
            for (i, &p) in ps.iter().enumerate() {
                // Reference: the old per-call clone-and-sort path, inlined
                // so the comparison is not circular through the delegation.
                let reference = if s.is_empty() {
                    0.0
                } else {
                    let mut v = s.values().to_vec();
                    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    v[((v.len() - 1) as f64 * p / 100.0).round() as usize]
                };
                if batched[i].to_bits() != reference.to_bits() {
                    return Err(format!(
                        "p{p}: batched {} != per-call {}",
                        batched[i], reference
                    ));
                }
            }
            Ok(())
        });
    }
}
