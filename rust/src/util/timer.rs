//! Wall-clock timing helpers used by the serving loop, the latency tables
//! (paper Table 6 / Fig. 7), and the micro-bench harness.

use std::time::Instant;

/// Collects duration samples and reports robust statistics.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    us: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, micros: f64) {
        self.us.push(micros);
    }

    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.push(t0.elapsed().as_secs_f64() * 1e6);
        out
    }

    pub fn len(&self) -> usize {
        self.us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.us.is_empty()
    }

    pub fn mean_us(&self) -> f64 {
        if self.us.is_empty() {
            return 0.0;
        }
        self.us.iter().sum::<f64>() / self.us.len() as f64
    }

    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.us.is_empty() {
            return 0.0;
        }
        let mut v = self.us.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() - 1) as f64 * p / 100.0).round() as usize;
        v[idx]
    }

    pub fn median_us(&self) -> f64 {
        self.percentile_us(50.0)
    }

    pub fn min_us(&self) -> f64 {
        self.us.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn stddev_us(&self) -> f64 {
        if self.us.len() < 2 {
            return 0.0;
        }
        let m = self.mean_us();
        let var = self.us.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.us.len() - 1) as f64;
        var.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats() {
        let mut s = Samples::new();
        for v in [1.0, 2.0, 3.0, 4.0, 100.0] {
            s.push(v);
        }
        assert_eq!(s.len(), 5);
        assert!((s.mean_us() - 22.0).abs() < 1e-9);
        assert_eq!(s.median_us(), 3.0);
        assert_eq!(s.min_us(), 1.0);
        assert!(s.percentile_us(100.0) == 100.0);
    }

    #[test]
    fn times_closure() {
        let mut s = Samples::new();
        let out = s.time(|| 41 + 1);
        assert_eq!(out, 42);
        assert_eq!(s.len(), 1);
        assert!(s.mean_us() >= 0.0);
    }
}
