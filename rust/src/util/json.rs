//! Minimal JSON parser + writer (serde_json is not in the offline vendor
//! set). Covers the subset used by `artifacts/manifest.json`, the pretrain
//! logs, and our report files: objects, arrays, strings (with escapes),
//! numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (stable key order; floats in shortest-roundtrip form).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // JSON has no NaN/Infinity literals: `{n}` would emit
                // `NaN`/`inf`, invalid JSON that silently breaks every
                // downstream jq/schema consumer. A non-finite sample is a
                // producer bug — assert loudly in debug builds, serialize
                // as null in release so the report stays parseable.
                debug_assert!(n.is_finite(), "non-finite number {n} in JSON output");
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected , or ] got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => {
                    // Re-decode multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }
}

/// Convenience builders for report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(
            v.req("b").unwrap().req("c").unwrap().as_str(),
            Some("hi\nthere")
        );
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_manifest_like() {
        let src = r#"{"models":{"sq-2m":{"config":{"d_model":128},"param_order":["emb","head"],
            "artifacts":{"fwd":{"file":"f.hlo.txt","inputs":[{"name":"emb","shape":[256,128],"dtype":"float32"}]}}}}}"#;
        let v = Json::parse(src).unwrap();
        let m = v.req("models").unwrap().req("sq-2m").unwrap();
        assert_eq!(m.req("config").unwrap().req("d_model").unwrap().as_usize(), Some(128));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{unquoted: 1}").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""AB""#).unwrap();
        assert_eq!(v.as_str(), Some("AB"));
    }

    /// Non-finite floats have no JSON literal: the writer must emit `null`
    /// (never `NaN`/`inf`, which every strict parser — including the CI jq
    /// schema gate — rejects). Debug builds assert on the producer bug, so
    /// this regression test pins the release-mode serialization.
    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "non-finite number"))]
    fn non_finite_numbers_serialize_as_null() {
        let v = arr(vec![num(f64::NAN), num(f64::INFINITY), num(f64::NEG_INFINITY), num(1.5)]);
        let s = v.to_string();
        assert_eq!(s, "[null,null,null,1.5]");
        // The output must round-trip through our own strict parser too.
        assert!(Json::parse(&s).is_ok());
    }
}
