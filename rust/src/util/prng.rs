//! Deterministic PRNG (SplitMix64 core) + Gaussian sampling.
//!
//! Every randomized piece of the pipeline (random rotations, Hadamard sign
//! diagonals, calibration sampling, synthetic task corruption) takes an
//! explicit seed so paper-figure sweeps (e.g. the 24-seed Fig. 4 histogram)
//! are exactly reproducible.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Prng {
    state: u64,
    /// Cached second Box-Muller sample.
    spare: Option<f32>,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f32::EPSILON {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f32::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Random ±1 sign.
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut p = Prng::new(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = p.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = p.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn choose_distinct() {
        let mut p = Prng::new(3);
        let got = p.choose(10, 4);
        assert_eq!(got.len(), 4);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
    }
}
