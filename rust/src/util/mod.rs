//! Substrate utilities: PRNG, JSON parsing, timing, logging.
//!
//! The offline vendor set contains only the `xla` crate's dependency
//! closure, so `rand` / `serde_json` / `log` are re-implemented here at the
//! (small) size this project needs.

pub mod json;
pub mod prng;
pub mod timer;

/// Simple leveled stderr logger, controlled by `SPINQUANT_LOG` (0..=2).
pub fn log_level() -> u8 {
    std::env::var("SPINQUANT_LOG")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 1 {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 2 {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}
