//! Serving path: token-by-token decode with a quantized KV-cache, latency
//! measurement (paper Table 6 / Fig. 7), and a threaded request scheduler.
//!
//! The decode artifacts (`decode_fp` / `decode_nohad` / `decode_had`) take
//! the whole KV cache as an input and return the updated cache; the
//! [`GenerationSession`] keeps the cache as PJRT literals between steps so
//! the steady-state loop does no tensor<->literal conversion for the cache.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::eval::QcfgVec;
use crate::model::Weights;
use crate::runtime::{Executable, Value};
use crate::util::timer::Samples;

/// Which decode artifact to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeVariant {
    Fp,
    QuantNoHad,
    QuantHad,
}

impl DecodeVariant {
    pub fn artifact(&self) -> &'static str {
        match self {
            DecodeVariant::Fp => "decode_fp",
            DecodeVariant::QuantNoHad => "decode_nohad",
            DecodeVariant::QuantHad => "decode_had",
        }
    }
}

/// One active generation with its KV cache.
pub struct GenerationSession<'e> {
    exe: &'e Executable,
    literals: Vec<xla::Literal>,
    token_idx: usize,
    pos_idx: usize,
    cache_k_idx: usize,
    cache_v_idx: usize,
    pub max_seq: usize,
    pub pos: usize,
    pub step_times: Samples,
}

impl<'e> GenerationSession<'e> {
    pub fn new(exe: &'e Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let mut values = Vec::with_capacity(exe.spec.inputs.len());
        let (mut token_idx, mut pos_idx, mut ck, mut cv) = (None, None, None, None);
        let mut max_seq = 0usize;
        for (i, (name, shape, _)) in exe.spec.inputs.iter().enumerate() {
            let v = match name.as_str() {
                "token" => {
                    token_idx = Some(i);
                    Value::I32(vec![0; shape.iter().product()], shape.clone())
                }
                "pos" => {
                    pos_idx = Some(i);
                    Value::ScalarI32(0)
                }
                "cache_k" => {
                    ck = Some(i);
                    max_seq = shape[2];
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "cache_v" => {
                    cv = Some(i);
                    Value::F32(crate::tensor::Tensor::zeros(shape))
                }
                "qcfg" => Value::F32(
                    qcfg.ok_or_else(|| anyhow!("{}: needs qcfg", exe.label))?.tensor(),
                ),
                _ => Value::F32(weights.get(name)?.clone()),
            };
            values.push(v);
        }
        let literals = exe.prepare(&values)?;
        Ok(Self {
            exe,
            literals,
            token_idx: token_idx.ok_or_else(|| anyhow!("no token input"))?,
            pos_idx: pos_idx.ok_or_else(|| anyhow!("no pos input"))?,
            cache_k_idx: ck.ok_or_else(|| anyhow!("no cache_k input"))?,
            cache_v_idx: cv.ok_or_else(|| anyhow!("no cache_v input"))?,
            max_seq,
            pos: 0,
            step_times: Samples::new(),
        })
    }

    /// Feed one token, advance the cache, return the logits (V,).
    pub fn step(&mut self, token: u8) -> Result<Vec<f32>> {
        if self.pos >= self.max_seq {
            anyhow::bail!("KV cache full ({} positions)", self.max_seq);
        }
        let t0 = Instant::now();
        self.literals[self.token_idx] =
            xla::Literal::vec1(&[token as i32]).reshape(&[1])?;
        self.literals[self.pos_idx] = xla::Literal::scalar(self.pos as i32);
        let bufs = self.exe.run_literals_raw(&self.literals)?;
        let result = bufs[0][0].to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        // outputs: logits, cache_k, cache_v — keep caches as literals.
        let cache_v = parts.pop().ok_or_else(|| anyhow!("missing cache_v"))?;
        let cache_k = parts.pop().ok_or_else(|| anyhow!("missing cache_k"))?;
        let logits_lit = parts.pop().ok_or_else(|| anyhow!("missing logits"))?;
        self.literals[self.cache_k_idx] = cache_k;
        self.literals[self.cache_v_idx] = cache_v;
        self.pos += 1;
        let logits = logits_lit.to_vec::<f32>()?;
        self.step_times.push(t0.elapsed().as_secs_f64() * 1e6);
        Ok(logits)
    }

    /// Greedy generation from a byte prompt.
    pub fn generate(&mut self, prompt: &[u8], n_new: usize) -> Result<Vec<u8>> {
        let mut last = Vec::new();
        for &b in prompt {
            last = self.step(b)?;
        }
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            if self.pos >= self.max_seq {
                break;
            }
            let next = argmax(&last) as u8;
            out.push(next);
            last = self.step(next)?;
        }
        Ok(out)
    }

    pub fn ms_per_token(&self) -> f64 {
        self.step_times.mean_us() / 1e3
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

// ---------------------------------------------------------------------------
// Request scheduler: a worker thread owns the PJRT state (it is !Send);
// clients submit prompts over a channel and receive completions.
// ---------------------------------------------------------------------------

/// A generation request.
pub struct Request {
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
}

/// A completed generation.
#[derive(Debug)]
pub struct Response {
    pub id: usize,
    pub completion: Vec<u8>,
    pub latency_ms: f64,
    pub ms_per_token: f64,
}

enum Msg {
    Submit(usize, Request),
    Shutdown,
}

/// Single-worker serving front: FIFO queue + per-request KV-cache reset.
/// (PJRT handles are not `Send`, so the worker thread constructs everything
/// it needs via the factory closure and owns it for its lifetime.)
pub struct Server {
    tx: mpsc::Sender<Msg>,
    rx_resp: mpsc::Receiver<Result<Response, String>>,
    handle: Option<std::thread::JoinHandle<()>>,
    next_id: usize,
}

impl Server {
    /// `factory` runs on the worker thread and must produce a closure that
    /// serves one request (typically wrapping a fresh GenerationSession).
    pub fn spawn<F, S>(factory: F) -> Self
    where
        F: FnOnce() -> Result<S> + Send + 'static,
        S: FnMut(&Request) -> Result<(Vec<u8>, f64)>,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (tx_resp, rx_resp) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            let mut serve_one = match factory() {
                Ok(s) => s,
                Err(e) => {
                    let _ = tx_resp.send(Err(format!("worker init failed: {e:#}")));
                    return;
                }
            };
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Submit(id, req) => {
                        let t0 = Instant::now();
                        let resp = serve_one(&req)
                            .map(|(completion, ms_per_token)| Response {
                                id,
                                completion,
                                latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                                ms_per_token,
                            })
                            .map_err(|e| format!("{e:#}"));
                        let _ = tx_resp.send(resp);
                    }
                    Msg::Shutdown => break,
                }
            }
        });
        Self { tx, rx_resp, handle: Some(handle), next_id: 0 }
    }

    pub fn submit(&mut self, req: Request) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        let _ = self.tx.send(Msg::Submit(id, req));
        id
    }

    pub fn recv(&self) -> Result<Response> {
        match self.rx_resp.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(anyhow!(e)),
            Err(_) => Err(anyhow!("server worker hung up")),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn server_round_trips_requests() {
        let mut server = Server::spawn(|| {
            Ok(move |req: &Request| {
                // Echo worker: "generates" the reversed prompt.
                let mut out = req.prompt.clone();
                out.reverse();
                out.truncate(req.max_new_tokens);
                Ok((out, 0.5))
            })
        });
        let id0 = server.submit(Request { prompt: b"abc".to_vec(), max_new_tokens: 8 });
        let id1 = server.submit(Request { prompt: b"hello".to_vec(), max_new_tokens: 2 });
        let r0 = server.recv().unwrap();
        let r1 = server.recv().unwrap();
        assert_eq!(r0.id, id0);
        assert_eq!(r0.completion, b"cba".to_vec());
        assert_eq!(r1.id, id1);
        assert_eq!(r1.completion, b"ol".to_vec());
    }
}
