//! LLM-QAT baseline (Liu et al. 2023): quantization-aware training with
//! straight-through estimators on weights, activations and the KV cache.
//!
//! The `qat_grads` artifact returns the loss and dL/dW for *every* weight
//! of the fully fake-quantized network; this module drives Adam over those
//! gradients. Substitution note (DESIGN.md §3): the original is data-free
//! (it self-generates data from the FP model); we train on the synthetic
//! calibration corpus instead, which exercises the identical QAT mechanics.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::eval::QcfgVec;
use crate::model::Weights;
use crate::runtime::Value;
use crate::tensor::Tensor;

use super::Pipeline;

struct Adam {
    m: Tensor,
    v: Tensor,
}

/// Train the folded weights with STE fake-quant at the pipeline's bit
/// widths; returns the adapted (still FP-valued) weights. The caller applies
/// the final deployment RTN pass so weights land exactly on the grid.
pub fn train(
    pipe: &Pipeline,
    folded: &Weights,
    meta: &mut BTreeMap<String, f64>,
) -> Result<Weights> {
    let cfg = &pipe.cfg;
    let exe = pipe.rt.load(pipe.manifest, &cfg.model, "qat_grads")?;

    let qcfg = QcfgVec::from_pipeline(cfg).with_w_bits(cfg.bits.w);
    let tokens_idx = exe.input_index("tokens")?;
    let (batch, seq) = {
        let (_, shape, _) = &exe.spec.inputs[tokens_idx];
        (shape[0], shape[1])
    };

    let order = pipe.model_cfg.param_order();
    let mut weights = folded.clone();
    let mut values = Vec::with_capacity(exe.spec.inputs.len());
    for (name, shape, _) in &exe.spec.inputs {
        let v = match name.as_str() {
            "tokens" => Value::I32(vec![0; shape.iter().product()], shape.clone()),
            "qcfg" => Value::F32(qcfg.tensor()),
            _ => Value::F32(weights.get(name)?.clone()),
        };
        values.push(v);
    }
    let mut literals = exe.prepare(&values)?;

    // Adam state per parameter.
    let mut state: BTreeMap<String, Adam> = order
        .iter()
        .map(|n| {
            let t = weights.get(n).unwrap();
            (
                n.clone(),
                Adam { m: Tensor::zeros(&t.shape.clone()), v: Tensor::zeros(&t.shape.clone()) },
            )
        })
        .collect();

    let corpus = pipe.load_corpus("train")?;
    let windows = corpus.calib_windows(seq, cfg.qat_steps * batch, cfg.calib_seed ^ 0x9A7);

    let (b1, b2, eps) = (0.9f32, 0.95f32, 1e-8f32);
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for step in 0..cfg.qat_steps {
        let start = (step * batch) % windows.len().max(1);
        let mut flat = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            flat.extend_from_slice(&windows[(start + b) % windows.len()]);
        }
        literals[tokens_idx] =
            xla::Literal::vec1(&flat).reshape(&[batch as i64, seq as i64])?;
        let outs = exe.run_literals(&literals)?;
        let loss = outs[0].data[0];
        first_loss.get_or_insert(loss);
        last_loss = loss;

        let t = (step + 1) as f32;
        let bc1 = 1.0 - b1.powf(t);
        let bc2 = 1.0 - b2.powf(t);
        // outputs: loss, then grads in param order.
        for (pi, name) in order.iter().enumerate() {
            let g = &outs[1 + pi];
            let w = weights.tensors.get_mut(name).unwrap();
            let st = state.get_mut(name).unwrap();
            for i in 0..w.data.len() {
                let gi = g.data[i];
                st.m.data[i] = b1 * st.m.data[i] + (1.0 - b1) * gi;
                st.v.data[i] = b2 * st.v.data[i] + (1.0 - b2) * gi * gi;
                let mhat = st.m.data[i] / bc1;
                let vhat = st.v.data[i] / bc2;
                w.data[i] -= cfg.qat_lr * mhat / (vhat.sqrt() + eps);
            }
        }
        // Refresh weight literals for the next step.
        for (ii, (name, _, _)) in exe.spec.inputs.iter().enumerate() {
            if name != "tokens" && name != "qcfg" {
                let t = weights.get(name)?;
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                literals[ii] = xla::Literal::vec1(&t.data).reshape(&dims)?;
            }
        }
        crate::debug!("qat step {step}: loss {loss:.4}");
    }
    meta.insert("qat_loss_first".into(), first_loss.unwrap_or(0.0) as f64);
    meta.insert("qat_loss_last".into(), last_loss as f64);
    Ok(weights)
}
