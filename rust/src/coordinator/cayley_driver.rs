//! Rotation learning loop (paper §3.2 / §4.1): drives Cayley SGD over the
//! `cayley_{nohad,had}` gradient artifacts.
//!
//! Division of labour: the PJRT artifact computes the Euclidean gradients
//! dL/dR1, dL/dR2ᵢ of the *activation-quantized* network loss (weights stay
//! FP — Table 3's winning configuration, unless
//! `cayley_on_quant_weights` asks for in-graph weight quant too); this rust
//! loop owns the Stiefel-manifold retraction, the 1.5 → 0 linear lr decay,
//! and the calibration batching.

use anyhow::Result;

use crate::cayley::{linear_decay_lr, CayleySgd, Solver};
use crate::eval::QcfgVec;
use crate::model::Weights;
use crate::rotation::RotationSet;
use crate::runtime::Value;
use crate::tensor::Tensor;

use super::Pipeline;

/// Outcome telemetry of one learning run.
#[derive(Clone, Debug)]
pub struct CayleyRun {
    pub losses: Vec<f32>,
    pub final_orth_error: f32,
}

/// Learn R1/R2 starting from `init`, minimizing the quantized-network loss.
pub fn learn_rotations(
    pipe: &Pipeline,
    folded_weights: &Weights,
    init: RotationSet,
    had: bool,
    meta: &mut std::collections::BTreeMap<String, f64>,
) -> Result<RotationSet> {
    let (rot, run) = learn_rotations_detailed(pipe, folded_weights, init, had)?;
    if let (Some(first), Some(last)) = (run.losses.first(), run.losses.last()) {
        meta.insert("cayley_loss_first".into(), *first as f64);
        meta.insert("cayley_loss_last".into(), *last as f64);
    }
    meta.insert("cayley_orth_error".into(), run.final_orth_error as f64);
    Ok(rot)
}

pub fn learn_rotations_detailed(
    pipe: &Pipeline,
    folded_weights: &Weights,
    init: RotationSet,
    had: bool,
) -> Result<(RotationSet, CayleyRun)> {
    let cfg = &pipe.cfg;

    let artifact = if had { "cayley_had" } else { "cayley_nohad" };
    let exe = pipe.rt.load(pipe.manifest, &cfg.model, artifact)?;

    // Rotation-learning qcfg: activations/KV at target bits; weights FP by
    // default (Table 3), optionally quantized in-graph for the ablation.
    let mut qcfg = QcfgVec::from_pipeline(cfg);
    if cfg.cayley_on_quant_weights {
        qcfg = qcfg.with_w_bits(cfg.bits.w);
    }

    // Locate dynamic inputs.
    let r1_idx = exe.input_index("r1")?;
    let r2s_idx = exe.input_index("r2s")?;
    let tokens_idx = exe.input_index("tokens")?;
    let (batch, seq) = {
        let (_, shape, _) = &exe.spec.inputs[tokens_idx];
        (shape[0], shape[1])
    };

    // Static inputs (weights + qcfg) as literals, once.
    let mut values = Vec::with_capacity(exe.spec.inputs.len());
    for (name, shape, _) in &exe.spec.inputs {
        let v = match name.as_str() {
            "r1" => Value::F32(init.r1.clone()),
            "r2s" => Value::F32(stack_r2s(&init.r2s)),
            "tokens" => Value::I32(vec![0; shape.iter().product()], shape.clone()),
            "qcfg" => Value::F32(qcfg.tensor()),
            _ => Value::F32(folded_weights.get(name)?.clone()),
        };
        values.push(v);
    }
    let mut literals = exe.prepare(&values)?;

    // Calibration windows: cfg.cayley_samples sequences, cycled in batches.
    let corpus = pipe.load_corpus("train")?;
    let windows = corpus.calib_windows(seq, cfg.cayley_samples.max(batch), cfg.calib_seed);

    let mut r1 = init.r1.clone();
    let mut r2s = init.r2s.clone();
    let mut opt_r1 = CayleySgd::new(cfg.cayley_lr, 0.9, Solver::Exact);
    let mut opt_r2: Vec<CayleySgd> =
        (0..r2s.len()).map(|_| CayleySgd::new(cfg.cayley_lr, 0.9, Solver::Exact)).collect();

    let mut losses = Vec::with_capacity(cfg.cayley_iters);
    for iter in 0..cfg.cayley_iters {
        // Batch for this iteration (cycled).
        let start = (iter * batch) % windows.len().max(1);
        let mut chunk: Vec<Vec<i32>> = Vec::with_capacity(batch);
        for b in 0..batch {
            chunk.push(windows[(start + b) % windows.len()].clone());
        }
        let flat: Vec<i32> = chunk.concat();
        literals[tokens_idx] =
            xla::Literal::vec1(&flat).reshape(&[batch as i64, seq as i64])?;
        literals[r1_idx] = tensor_literal(&r1)?;
        literals[r2s_idx] = tensor_literal(&stack_r2s(&r2s))?;

        let outs = exe.run_literals(&literals)?;
        let loss = outs[0].data[0];
        losses.push(loss);
        let g1 = &outs[1];
        let g2s = &outs[2];

        let lr = linear_decay_lr(cfg.cayley_lr, iter, cfg.cayley_iters);
        // R2 steps use a head-dim-scaled lr (same schedule, smaller matrices).
        opt_r1.step(&mut r1, g1, lr)?;
        for (l, opt) in opt_r2.iter_mut().enumerate() {
            let g2 = g2s.index0(l);
            opt.step(&mut r2s[l], &g2, lr)?;
        }
        crate::debug!("cayley iter {iter}: loss {loss:.4} lr {lr:.3}");
    }

    let rot = RotationSet { r1, r2s };
    let run = CayleyRun { final_orth_error: rot.orthonormality_error(), losses };
    Ok((rot, run))
}

fn stack_r2s(r2s: &[Tensor]) -> Tensor {
    let l = r2s.len();
    let dh = r2s[0].shape[0];
    let mut out = Tensor::zeros(&[l, dh, dh]);
    for (i, r) in r2s.iter().enumerate() {
        out.data[i * dh * dh..(i + 1) * dh * dh].copy_from_slice(&r.data);
    }
    out
}

fn tensor_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_r2s_layout() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![5., 6., 7., 8.]);
        let s = stack_r2s(&[a.clone(), b.clone()]);
        assert_eq!(s.shape, vec![2, 2, 2]);
        assert_eq!(s.index0(0), a);
        assert_eq!(s.index0(1), b);
    }
}
