//! The SpinQuant pipeline coordinator — L3's brain.
//!
//! Orchestrates: weight loading → RMSNorm folding → rotation construction /
//! Cayley learning → rotation merging → weight quantization (RTN/GPTQ) →
//! evaluation (perplexity + zero-shot) → reporting. Every paper method
//! (Table 1 row family) is a branch of [`Pipeline::quantize`].
//!
//! Submodules: [`cayley_driver`] (rotation learning loop over the PJRT grad
//! artifact) and [`qat`] (LLM-QAT baseline trainer). The decode loop,
//! KV-cache slot manager and request scheduler were promoted to the
//! top-level [`crate::serve`] subsystem (continuous batching); the old
//! `coordinator::serve` path is re-exported for compatibility.

pub mod cayley_driver;
pub mod qat;

pub use crate::serve;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::{Method, PipelineConfig};
use crate::data::Corpus;
use crate::eval::{self, EvalSession, QcfgVec};
use crate::gptq::HessianAccum;
use crate::hadamard;
use crate::model::{Manifest, ModelConfig, Weights};
use crate::rotation::{self, RotationKind, RotationSet};
use crate::runtime::{Executable, Runtime};
use crate::smoothquant;
use crate::tensor::Tensor;

/// The result of the quantization pipeline: everything the eval/serving
/// path needs. Weights are stored dequantized (the artifacts consume f32),
/// with the integer grids already applied.
pub struct QuantizedModel {
    pub weights: Weights,
    pub qcfg: QcfgVec,
    pub had: bool,
    pub rotation: Option<RotationSet>,
    /// Pipeline telemetry (cayley loss curve endpoints, timings...).
    pub meta: BTreeMap<String, f64>,
}

/// Per-linear calibration captures from one or more `fwd_stats` runs.
pub struct CalibStats {
    /// site -> stacked capture (layers, rows, dim); head_in has layers=1.
    pub captures: BTreeMap<String, Tensor>,
}

pub struct Pipeline<'rt> {
    pub rt: &'rt Runtime,
    pub manifest: &'rt Manifest,
    pub cfg: PipelineConfig,
    pub model_cfg: ModelConfig,
}

impl<'rt> Pipeline<'rt> {
    pub fn new(rt: &'rt Runtime, manifest: &'rt Manifest, cfg: PipelineConfig) -> Result<Self> {
        let model_cfg = manifest.config(&cfg.model)?;
        manifest.check_param_order(&model_cfg)?;
        Ok(Self { rt, manifest, cfg, model_cfg })
    }

    pub fn load_base_weights(&self) -> Result<Weights> {
        let w = Weights::load(&self.manifest.weights_path(&self.cfg.model))?;
        w.validate(&self.model_cfg)?;
        Ok(w)
    }

    pub fn load_corpus(&self, split: &str) -> Result<Corpus> {
        Corpus::load(&self.manifest.data_path(&self.cfg.calib_corpus, split))
    }

    fn fwd_artifact_name(had: bool, kind: &str) -> String {
        format!("fwd_{kind}_{}", if had { "had" } else { "nohad" })
    }

    /// Run fwd_stats over `n_batches` calibration batches and accumulate
    /// per-site captures (concatenated along the row axis).
    pub fn collect_stats(&self, weights: &Weights, n_batches: usize) -> Result<CalibStats> {
        let exe = self.rt.load(self.manifest, &self.cfg.model, "fwd_stats")?;
        let mut session = EvalSession::new(&exe, weights, None)?;
        let corpus = self.load_corpus("train")?;
        let windows = corpus.calib_windows(
            session.seq,
            n_batches * session.batch,
            self.cfg.calib_seed ^ 0x57A75,
        );
        let out_names = exe.spec.outputs.clone();
        let mut captures: BTreeMap<String, Tensor> = BTreeMap::new();
        for chunk in windows.chunks(session.batch) {
            let outs = session.run(chunk)?;
            for (name, t) in out_names.iter().zip(outs) {
                if name == "logits" {
                    continue;
                }
                // Normalize to (layers, rows, dim).
                let norm = normalize_capture(name, &t, &self.model_cfg);
                captures
                    .entry(name.clone())
                    .and_modify(|acc| *acc = concat_rows(acc, &norm))
                    .or_insert(norm);
            }
        }
        Ok(CalibStats { captures })
    }

    /// GPTQ Hessian accumulation from the stats captures.
    /// `had`: apply the online R4 Hadamard to the down_proj input capture
    /// (the stats artifact taps pre-R4; the real `_had` network quantizes
    /// post-R4 against the H-merged w_down).
    fn hessians(&self, stats: &CalibStats, had: bool) -> Result<BTreeMap<String, HessianAccum>> {
        let cfg = &self.model_cfg;
        let mut hs: BTreeMap<String, HessianAccum> = BTreeMap::new();
        let mut feed = |name: String, x: &Tensor| {
            let k = x.last_dim();
            hs.entry(name).or_insert_with(|| HessianAccum::new(k)).add_batch(x);
        };
        for l in 0..cfg.n_layers {
            let p = format!("layers.{l}.");
            let resid = stats.captures["resid_in"].index0(l);
            for w in ["wq", "wk", "wv"] {
                feed(format!("{p}{w}"), &resid);
            }
            feed(format!("{p}wo"), &stats.captures["oproj_in"].index0(l));
            let ffn = stats.captures["ffn_in"].index0(l);
            feed(format!("{p}wgate"), &ffn);
            feed(format!("{p}wup"), &ffn);
            let mut down = stats.captures["down_in"].index0(l);
            if had {
                down = hadamard::fwht_last_axis(&down);
            }
            feed(format!("{p}wdown"), &down);
        }
        feed("head".to_string(), &stats.captures["head_in"].index0(0));
        Ok(hs)
    }

    /// Quantize every linear weight (RTN or GPTQ). Norms and the embedding
    /// stay FP (standard in all compared methods).
    fn quantize_weights(
        &self,
        weights: &Weights,
        hessians: Option<&BTreeMap<String, HessianAccum>>,
    ) -> Result<Weights> {
        let bits = self.cfg.bits.w;
        if bits >= 16.0 {
            return Ok(weights.clone());
        }
        let mut out = weights.clone();
        for name in self.model_cfg.param_order() {
            let is_linear = name.ends_with("wq")
                || name.ends_with("wk")
                || name.ends_with("wv")
                || name.ends_with("wo")
                || name.ends_with("wgate")
                || name.ends_with("wup")
                || name.ends_with("wdown")
                || name == "head";
            if !is_linear {
                continue;
            }
            let w = weights.get(&name)?;
            let q = match hessians.and_then(|h| h.get(&name)) {
                Some(h) => crate::gptq::gptq_quantize(w, h, bits, self.cfg.gptq_percdamp)
                    .with_context(|| format!("GPTQ on {name}"))?,
                None => crate::gptq::rtn_quantize(w, bits),
            };
            out.set(&name, q);
        }
        Ok(out)
    }

    fn rotation_kind(&self) -> Result<RotationKind> {
        Ok(match self.cfg.rotation_init.as_str() {
            "hadamard" => RotationKind::RandomHadamard,
            "orthogonal" | "fp" => RotationKind::RandomOrthogonal,
            "identity" => RotationKind::Identity,
            other => bail!("unknown rotation_init {other:?}"),
        })
    }

    /// The full quantization pipeline for the configured method.
    pub fn quantize(&self) -> Result<QuantizedModel> {
        let t0 = std::time::Instant::now();
        let mut meta = BTreeMap::new();
        let base = self.load_base_weights()?;
        let folded = rotation::fold_norm_scales(&base, &self.model_cfg)?;
        let method = self.cfg.method;
        let had = method.uses_online_hadamard();
        let qcfg = match method {
            Method::Float => QcfgVec::fp(),
            _ => QcfgVec::from_pipeline(&self.cfg),
        };

        let (weights, rotation) = match method {
            Method::Float => (folded, None),
            Method::Rtn => (self.quantize_weights(&folded, None)?, None),
            Method::Gptq => {
                let stats = self.collect_stats(&folded, self.cfg.gptq_batches)?;
                let hs = self.hessians(&stats, false)?;
                (self.quantize_weights(&folded, Some(&hs))?, None)
            }
            Method::SmoothQuant => {
                let stats = self.collect_stats(&folded, self.cfg.gptq_batches)?;
                let mut act = smoothquant::ActStats::new(&self.model_cfg);
                for l in 0..self.model_cfg.n_layers {
                    smoothquant::ActStats::absorb(
                        &mut act.attn_in[l],
                        &stats.captures["resid_in"].index0(l),
                    );
                    smoothquant::ActStats::absorb(
                        &mut act.ffn_in[l],
                        &stats.captures["ffn_in"].index0(l),
                    );
                }
                smoothquant::ActStats::absorb(
                    &mut act.head_in,
                    &stats.captures["head_in"].index0(0),
                );
                let smoothed = smoothquant::apply(&folded, &self.model_cfg, &act, 0.5)?;
                (self.quantize_weights(&smoothed, None)?, None)
            }
            Method::LlmQat => {
                let trained = qat::train(self, &folded, &mut meta)?;
                (self.quantize_weights(&trained, None)?, None)
            }
            Method::QuaRot => {
                // Random Hadamard R1/R2 + online R3/R4, no learning.
                return self.quantize_rotated(
                    RotationKind::RandomHadamard,
                    self.cfg.rotation_seed,
                    false,
                    true,
                );
            }
            Method::SpinQuantNoHad | Method::SpinQuantHad => {
                return self.quantize_rotated(
                    self.rotation_kind()?,
                    self.cfg.rotation_seed,
                    true,
                    had,
                );
            }
        };

        meta.insert("pipeline_seconds".into(), t0.elapsed().as_secs_f64());
        Ok(QuantizedModel { weights, qcfg, had, rotation, meta })
    }

    /// The rotation-family pipeline (QuaRot / SpinQuant / the Table 2 & 4
    /// ablation arms): build or learn R1/R2, merge, weight-quantize.
    /// Exposed so the bench harnesses can sweep (kind, seed, learn, had)
    /// combinations directly.
    pub fn quantize_rotated(
        &self,
        kind: RotationKind,
        seed: u64,
        learn: bool,
        had: bool,
    ) -> Result<QuantizedModel> {
        let t0 = std::time::Instant::now();
        let mut meta = BTreeMap::new();
        let base = self.load_base_weights()?;
        let folded = rotation::fold_norm_scales(&base, &self.model_cfg)?;
        let qcfg = QcfgVec::from_pipeline(&self.cfg);
        let init = RotationSet::build(&self.model_cfg, kind, seed);
        let rot = if learn {
            cayley_driver::learn_rotations(self, &folded, init, had, &mut meta)?
        } else {
            init
        };
        let merged = rotation::merge(&folded, &self.model_cfg, &rot, had)?;
        let hs = if self.cfg.use_gptq && self.cfg.bits.w < 16.0 {
            let stats = self.collect_stats(&merged, self.cfg.gptq_batches)?;
            Some(self.hessians(&stats, had)?)
        } else {
            None
        };
        let weights = self.quantize_weights(&merged, hs.as_ref())?;
        meta.insert("pipeline_seconds".into(), t0.elapsed().as_secs_f64());
        Ok(QuantizedModel { weights, qcfg, had, rotation: Some(rot), meta })
    }

    /// Load the forward executable matching a quantized model.
    pub fn fwd_exe(&self, qm: &QuantizedModel, kind: &str) -> Result<Executable> {
        self.rt.load(self.manifest, &self.cfg.model, &Self::fwd_artifact_name(qm.had, kind))
    }

    /// Full paper-style evaluation: Wiki perplexity + 0-shot^8 average.
    pub fn evaluate(&self, qm: &QuantizedModel) -> Result<EvalResult> {
        let test = self.load_corpus("test")?;
        // Perplexity.
        let eval_exe = self.fwd_exe(qm, "eval")?;
        let mut session = EvalSession::new(&eval_exe, &qm.weights, Some(qm.qcfg))?;
        let windows = test.eval_windows(session.seq, self.cfg.eval_windows);
        let ppl = eval::perplexity(&mut session, &windows)?;
        drop(session);

        // Zero-shot tasks.
        let task_exe = self.fwd_exe(qm, "task")?;
        let mut tsession = EvalSession::new(&task_exe, &qm.weights, Some(qm.qcfg))?;
        let other = Corpus::load(&self.manifest.data_path("c4-syn", "test")).ok();
        let seq = tsession.seq;
        let suites = crate::data::build_task_suites(
            &test,
            other.as_ref(),
            self.cfg.task_items,
            seq / 2,
            seq / 2,
            4,
            0xBEEF,
        );
        let (per_suite, avg) = eval::zero_shot(&mut tsession, &suites)?;
        Ok(EvalResult { ppl, per_suite, zero_shot_avg: avg })
    }
}

/// Evaluation outcome for one (method, bits, model) cell of Table 1.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub ppl: f64,
    pub per_suite: Vec<(String, f64)>,
    pub zero_shot_avg: f64,
}

impl EvalResult {
    /// Accuracy in percent, like the paper's tables.
    pub fn acc_pct(&self) -> f64 {
        self.zero_shot_avg * 100.0
    }
}

/// Normalize a capture tensor to (layers, rows, dim).
fn normalize_capture(name: &str, t: &Tensor, cfg: &ModelConfig) -> Tensor {
    match name {
        // (L, B, S, D) or (L, B, S, F)
        "resid_in" | "oproj_in" | "ffn_in" | "down_in" => {
            let l = t.shape[0];
            let d = *t.shape.last().unwrap();
            let rows = t.numel() / (l * d);
            t.clone().reshape(&[l, rows, d]).unwrap()
        }
        // (L, B, S, H, dh) -> per-head rows
        "k" | "v" => {
            let l = t.shape[0];
            let dh = cfg.d_head;
            let rows = t.numel() / (l * dh);
            t.clone().reshape(&[l, rows, dh]).unwrap()
        }
        // (B, S, D) -> (1, rows, D)
        "head_in" => {
            let d = *t.shape.last().unwrap();
            let rows = t.numel() / d;
            t.clone().reshape(&[1, rows, d]).unwrap()
        }
        _ => t.clone(),
    }
}

fn concat_rows(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape[0], b.shape[0]);
    assert_eq!(a.shape[2], b.shape[2]);
    let (l, ra, d) = (a.shape[0], a.shape[1], a.shape[2]);
    let rb = b.shape[1];
    let mut out = Tensor::zeros(&[l, ra + rb, d]);
    for layer in 0..l {
        let dst = &mut out.data[layer * (ra + rb) * d..];
        dst[..ra * d].copy_from_slice(&a.data[layer * ra * d..(layer + 1) * ra * d]);
        dst[ra * d..(ra + rb) * d]
            .copy_from_slice(&b.data[layer * rb * d..(layer + 1) * rb * d]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_rows_stacks_per_layer() {
        let a = Tensor::new(vec![2, 1, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2, 2], vec![5., 6., 7., 8., 9., 10., 11., 12.]);
        let c = concat_rows(&a, &b);
        assert_eq!(c.shape, vec![2, 3, 2]);
        assert_eq!(c.index0(0).data, vec![1., 2., 5., 6., 7., 8.]);
        assert_eq!(c.index0(1).data, vec![3., 4., 9., 10., 11., 12.]);
    }

    #[test]
    fn normalize_capture_shapes() {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 256,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ffn: 16,
            rope_theta: 1e4,
            max_seq: 8,
            n_params: 0,
        };
        let t = Tensor::zeros(&[2, 3, 5, 8]);
        assert_eq!(normalize_capture("resid_in", &t, &cfg).shape, vec![2, 15, 8]);
        let kv = Tensor::zeros(&[2, 3, 5, 2, 4]);
        assert_eq!(normalize_capture("k", &kv, &cfg).shape, vec![2, 30, 4]);
        let h = Tensor::zeros(&[3, 5, 8]);
        assert_eq!(normalize_capture("head_in", &h, &cfg).shape, vec![1, 15, 8]);
    }
}
