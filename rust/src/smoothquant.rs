//! SmoothQuant baseline (Xiao et al. 2023) — per-channel difficulty
//! migration from activations to weights.
//!
//! For every linear that reads a *scaled* input (q/k/v after attn_norm,
//! gate/up after ffn_norm, head after final_norm) compute
//! `s_j = max|X_j|^alpha / max|W_j|^(1-alpha)`, then fold `1/s` into the preceding RMSNorm gamma and `s` into the weight
//! rows. The quantized network sees activations divided by `s` (smoothed)
//! and weights multiplied by `s` — function unchanged in full precision.
//!
//! o-proj and down-proj inputs have no preceding static scale in a LLaMA
//! block, so (as in the reference implementation) they are left untouched.
//! Activation absmax statistics come from the `fwd_stats` artifact taps.

use anyhow::Result;

use crate::model::{ModelConfig, Weights};
use crate::tensor::Tensor;

/// Per-channel activation absmax for each smoothing site.
#[derive(Clone, Debug, Default)]
pub struct ActStats {
    /// `resid_in[layer][channel]` — input to wq/wk/wv (post attn_norm).
    pub attn_in: Vec<Vec<f32>>,
    /// `ffn_in[layer][channel]` — input to wgate/wup (post ffn_norm).
    pub ffn_in: Vec<Vec<f32>>,
    /// input to the head (post final_norm).
    pub head_in: Vec<f32>,
}

impl ActStats {
    pub fn new(cfg: &ModelConfig) -> Self {
        Self {
            attn_in: vec![vec![0.0; cfg.d_model]; cfg.n_layers],
            ffn_in: vec![vec![0.0; cfg.d_model]; cfg.n_layers],
            head_in: vec![0.0; cfg.d_model],
        }
    }

    /// Fold a capture tensor of shape (..., d) into a per-channel absmax.
    pub fn absorb(acc: &mut [f32], t: &Tensor) {
        let d = t.last_dim();
        assert_eq!(acc.len(), d);
        for r in 0..t.rows_2d() {
            for (a, &v) in acc.iter_mut().zip(t.row(r)) {
                *a = a.max(v.abs());
            }
        }
    }
}

/// Per-channel weight absmax across a set of row-indexed weights.
fn weight_absmax_rows(ws: &[&Tensor]) -> Vec<f32> {
    let d = ws[0].shape[0];
    let mut out = vec![0.0f32; d];
    for w in ws {
        assert_eq!(w.shape[0], d);
        let n = w.shape[1];
        for (i, acc) in out.iter_mut().enumerate() {
            for j in 0..n {
                *acc = acc.max(w.data[i * n + j].abs());
            }
        }
    }
    out
}

fn smoothing_scales(act_max: &[f32], w_max: &[f32], alpha: f32) -> Vec<f32> {
    act_max
        .iter()
        .zip(w_max)
        .map(|(&a, &w)| {
            let s = a.max(1e-5).powf(alpha) / w.max(1e-5).powf(1.0 - alpha);
            s.clamp(1e-3, 1e3)
        })
        .collect()
}

/// Apply SmoothQuant: returns smoothed weights (gammas updated in place in
/// the returned set). `alpha` defaults to the paper's 0.5.
pub fn apply(w: &Weights, cfg: &ModelConfig, stats: &ActStats, alpha: f32) -> Result<Weights> {
    let mut out = w.clone();

    let scale_site = |out: &mut Weights,
                      norm_name: &str,
                      weight_names: &[String],
                      act_max: &[f32]|
     -> Result<()> {
        let ws: Vec<&Tensor> =
            weight_names.iter().map(|n| w.get(n)).collect::<Result<_>>()?;
        let wmax = weight_absmax_rows(&ws);
        let s = smoothing_scales(act_max, &wmax, alpha);
        // gamma <- gamma / s
        let gamma = w.get(norm_name)?;
        let new_gamma = Tensor::new(
            gamma.shape.clone(),
            gamma.data.iter().zip(&s).map(|(g, sv)| g / sv).collect(),
        );
        out.set(norm_name, new_gamma);
        // W <- diag(s) W
        for name in weight_names {
            let t = w.get(name)?;
            let (d, n) = (t.shape[0], t.shape[1]);
            let mut r = t.clone();
            for i in 0..d {
                for j in 0..n {
                    r.data[i * n + j] *= s[i];
                }
            }
            out.set(name, r);
        }
        Ok(())
    };

    for i in 0..cfg.n_layers {
        let p = format!("layers.{i}.");
        scale_site(
            &mut out,
            &format!("{p}attn_norm"),
            &[format!("{p}wq"), format!("{p}wk"), format!("{p}wv")],
            &stats.attn_in[i],
        )?;
        scale_site(
            &mut out,
            &format!("{p}ffn_norm"),
            &[format!("{p}wgate"), format!("{p}wup")],
            &stats.ffn_in[i],
        )?;
    }
    scale_site(&mut out, "final_norm", &["head".to_string()], &stats.head_in)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 13,
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            d_head: 4,
            d_ffn: 16,
            rope_theta: 10000.0,
            max_seq: 16,
            n_params: 0,
        }
    }

    fn weights(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut p = Prng::new(seed);
        let mut w = Weights::new();
        for name in cfg.param_order() {
            let shape = cfg.param_shape(&name).unwrap();
            let n: usize = shape.iter().product();
            let data = if name.ends_with("norm") {
                vec![1.0; n]
            } else {
                (0..n).map(|_| p.normal() * 0.2).collect()
            };
            w.set(&name, Tensor::new(shape, data));
        }
        w
    }

    #[test]
    fn scales_balance_outliers() {
        let act = vec![100.0, 1.0, 1.0, 1.0];
        let wmx = vec![0.1, 0.1, 0.1, 0.1];
        let s = smoothing_scales(&act, &wmx, 0.5);
        assert!(s[0] > 5.0 * s[1], "outlier channel should get big scale: {s:?}");
    }

    #[test]
    fn function_preserved_in_fp() {
        // gamma/s composed with diag(s) W must be the identity transform:
        // (x * gamma/s) @ (diag(s) W) == (x * gamma) @ W.
        let c = cfg();
        let w = weights(&c, 1);
        let mut stats = ActStats::new(&c);
        let mut p = Prng::new(2);
        for l in 0..c.n_layers {
            for v in stats.attn_in[l].iter_mut() {
                *v = p.uniform() * 10.0 + 0.1;
            }
            for v in stats.ffn_in[l].iter_mut() {
                *v = p.uniform() * 10.0 + 0.1;
            }
        }
        for v in stats.head_in.iter_mut() {
            *v = p.uniform() * 10.0 + 0.1;
        }
        let sm = apply(&w, &c, &stats, 0.5).unwrap();
        // simulate the site: x (rows, d) normalized input
        let x = Tensor::new(vec![4, 8], (0..32).map(|_| p.normal()).collect());
        let site = |wts: &Weights, norm: &str, lin: &str| -> Tensor {
            let g = wts.get(norm).unwrap();
            let mut xg = x.clone();
            for r in 0..4 {
                for j in 0..8 {
                    xg.data[r * 8 + j] *= g.data[j];
                }
            }
            crate::linalg::matmul(&xg, wts.get(lin).unwrap())
        };
        let base = site(&w, "layers.0.attn_norm", "layers.0.wq");
        let smoothed = site(&sm, "layers.0.attn_norm", "layers.0.wq");
        assert!(base.sub(&smoothed).max_abs() < 1e-4);
    }

    #[test]
    fn smoothing_reduces_activation_outlier_difficulty() {
        // After folding gamma/s, the effective activation seen by the
        // quantizer is x/s: outlier channels shrink.
        let act = vec![80.0, 1.0, 1.0, 2.0];
        let wmx = vec![0.5, 0.5, 0.5, 0.5];
        let s = smoothing_scales(&act, &wmx, 0.5);
        let effective: Vec<f32> = act.iter().zip(&s).map(|(a, sv)| a / sv).collect();
        let spread_before = act.iter().cloned().fold(0.0f32, f32::max)
            / act.iter().cloned().fold(f32::INFINITY, f32::min);
        let spread_after = effective.iter().cloned().fold(0.0f32, f32::max)
            / effective.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(spread_after < spread_before * 0.5);
    }

    #[test]
    fn absorb_tracks_max() {
        let mut acc = vec![0.0f32; 3];
        let t = Tensor::new(vec![2, 3], vec![1., -5., 2., 3., 1., -1.]);
        ActStats::absorb(&mut acc, &t);
        assert_eq!(acc, vec![3.0, 5.0, 2.0]);
    }
}
