//! SQT tensor container IO — byte-compatible with python/compile/sqt.py.
//!
//! Layout (little-endian): magic "SQT1", u32 count, then per tensor:
//! u16 name_len, name, u8 ndim, u32×ndim dims, f32×numel data.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

pub fn write_sqt(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?,
    );
    f.write_all(b"SQT1")?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        if nb.len() > u16::MAX as usize {
            bail!("tensor name too long");
        }
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[t.ndim() as u8])?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        // Bulk write the payload.
        let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
    }
    Ok(())
}

pub fn read_sqt(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"SQT1" {
        bail!("{path:?}: bad SQT magic {magic:?}");
    }
    let mut buf4 = [0u8; 4];
    f.read_exact(&mut buf4)?;
    let count = u32::from_le_bytes(buf4);
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let mut buf2 = [0u8; 2];
        f.read_exact(&mut buf2)?;
        let name_len = u16::from_le_bytes(buf2) as usize;
        let mut name_bytes = vec![0u8; name_len];
        f.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes)?;
        let mut nd = [0u8; 1];
        f.read_exact(&mut nd)?;
        let mut shape = Vec::with_capacity(nd[0] as usize);
        for _ in 0..nd[0] {
            f.read_exact(&mut buf4)?;
            shape.push(u32::from_le_bytes(buf4) as usize);
        }
        let numel: usize = shape.iter().product::<usize>().max(if nd[0] == 0 { 1 } else { 0 });
        let mut bytes = vec![0u8; numel * 4];
        f.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.insert(name, Tensor::new(shape, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sqt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.sqt");
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        m.insert("b.long/name".to_string(), Tensor::scalar(-7.25));
        m.insert("c".to_string(), Tensor::zeros(&[4]));
        write_sqt(&path, &m).unwrap();
        let back = read_sqt(&path).unwrap();
        assert_eq!(m.len(), back.len());
        for (k, v) in &m {
            assert_eq!(&back[k], v, "{k}");
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("sqt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.sqt");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_sqt(&path).is_err());
    }
}
