//! Model definition mirror: configs, weight store, SQT container IO, and
//! the artifact manifest. The *authoritative* compute graphs live in L2
//! (python/compile/model.py); this module owns the runtime-side metadata
//! and weight manipulation the quantization pipeline needs.

pub mod sqt;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;

/// Mirror of python `compile.model.Config` (values come from the manifest,
/// so the two sides cannot drift silently).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub rope_theta: f32,
    pub max_seq: usize,
    pub n_params: usize,
}

impl ModelConfig {
    pub fn from_json(name: &str, j: &Json) -> Result<Self> {
        let u = |k: &str| -> Result<usize> {
            j.req(k)?.as_usize().ok_or_else(|| anyhow!("config key {k} not a number"))
        };
        Ok(Self {
            name: name.to_string(),
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            d_head: u("d_head")?,
            d_ffn: u("d_ffn")?,
            rope_theta: j.req("rope_theta")?.as_f64().unwrap_or(10000.0) as f32,
            max_seq: u("max_seq")?,
            n_params: u("n_params")?,
        })
    }

    /// Canonical parameter order — must equal python `model.param_order`.
    pub fn param_order(&self) -> Vec<String> {
        let mut names = vec!["emb".to_string()];
        for i in 0..self.n_layers {
            for suffix in
                ["attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "wgate", "wup", "wdown"]
            {
                names.push(format!("layers.{i}.{suffix}"));
            }
        }
        names.push("final_norm".to_string());
        names.push("head".to_string());
        names
    }

    pub fn param_shape(&self, name: &str) -> Result<Vec<usize>> {
        let (d, f, v) = (self.d_model, self.d_ffn, self.vocab);
        let hd = self.n_heads * self.d_head;
        let shape = if name == "emb" {
            vec![v, d]
        } else if name == "head" {
            vec![d, v]
        } else if name == "final_norm" {
            vec![d]
        } else if let Some(rest) = name.split('.').nth(2) {
            match rest {
                "attn_norm" | "ffn_norm" => vec![d],
                "wq" | "wk" | "wv" => vec![d, hd],
                "wo" => vec![hd, d],
                "wgate" | "wup" => vec![d, f],
                "wdown" => vec![f, d],
                _ => bail!("unknown param {name}"),
            }
        } else {
            bail!("unknown param {name}");
        };
        Ok(shape)
    }
}

/// A full set of model weights, keyed by canonical names.
#[derive(Clone, Debug)]
pub struct Weights {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn new() -> Self {
        Self { tensors: BTreeMap::new() }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("missing weight {name}"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn load(path: &Path) -> Result<Self> {
        let tensors = sqt::read_sqt(path).with_context(|| format!("loading {path:?}"))?;
        Ok(Self { tensors })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        sqt::write_sqt(path, &self.tensors)
    }

    /// Verify every canonical parameter exists with the right shape.
    pub fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        for name in cfg.param_order() {
            let t = self.get(&name)?;
            let want = cfg.param_shape(&name)?;
            if t.shape != want {
                bail!("weight {name}: shape {:?}, expected {want:?}", t.shape);
            }
        }
        Ok(())
    }

    /// Tensors in canonical artifact-input order.
    pub fn ordered(&self, cfg: &ModelConfig) -> Result<Vec<&Tensor>> {
        cfg.param_order().iter().map(|n| self.get(n)).collect()
    }

    /// Map over every weight tensor (by name) into a new set.
    pub fn map(&self, f: impl Fn(&str, &Tensor) -> Tensor) -> Self {
        let tensors =
            self.tensors.iter().map(|(k, v)| (k.clone(), f(k, v))).collect::<BTreeMap<_, _>>();
        Self { tensors }
    }
}

impl Default for Weights {
    fn default() -> Self {
        Self::new()
    }
}

/// Parsed artifacts/manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: std::path::PathBuf,
    json: Json,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    /// (name, shape, dtype) in execution order.
    pub inputs: Vec<(String, Vec<usize>, String)>,
    pub outputs: Vec<String>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        Ok(Self { root: artifacts_dir.to_path_buf(), json: Json::parse(&text)? })
    }

    pub fn models(&self) -> Vec<String> {
        self.json
            .get("models")
            .and_then(|m| m.as_obj())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    pub fn config(&self, model: &str) -> Result<ModelConfig> {
        let j = self.json.req("models")?.req(model)?.req("config")?;
        ModelConfig::from_json(model, j)
    }

    /// Assert python and rust agree on the parameter ABI.
    pub fn check_param_order(&self, cfg: &ModelConfig) -> Result<()> {
        let j = self.json.req("models")?.req(&cfg.name)?.req("param_order")?;
        let py: Vec<&str> =
            j.as_arr().unwrap_or(&[]).iter().filter_map(|v| v.as_str()).collect();
        let rs = cfg.param_order();
        if py.len() != rs.len() || py.iter().zip(&rs).any(|(a, b)| a != b) {
            bail!("param_order mismatch between manifest and rust for {}", cfg.name);
        }
        Ok(())
    }

    pub fn artifact(&self, model: &str, name: &str) -> Result<ArtifactSpec> {
        let j = self.json.req("models")?.req(model)?.req("artifacts")?.req(name)?;
        let file = j.req("file")?.as_str().unwrap_or_default().to_string();
        let mut inputs = Vec::new();
        for inp in j.req("inputs")?.as_arr().unwrap_or(&[]) {
            let n = inp.req("name")?.as_str().unwrap_or_default().to_string();
            let shape = inp
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            let dtype = inp.req("dtype")?.as_str().unwrap_or("float32").to_string();
            inputs.push((n, shape, dtype));
        }
        let outputs = j
            .req("outputs")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect();
        Ok(ArtifactSpec { file, inputs, outputs })
    }

    /// Names of every artifact recorded for `model` (empty for an unknown
    /// model) — lets callers discover what the build emitted (e.g. which
    /// `prefill_*_t{T}` chunk sizes exist) without hard-coding the zoo.
    pub fn artifact_names(&self, model: &str) -> Vec<String> {
        self.json
            .get("models")
            .and_then(|m| m.get(model))
            .and_then(|m| m.get("artifacts"))
            .and_then(|a| a.as_obj())
            .map(|a| a.keys().cloned().collect())
            .unwrap_or_default()
    }

    pub fn weights_path(&self, model: &str) -> std::path::PathBuf {
        self.root.join("weights").join(format!("{model}.sqt"))
    }

    pub fn data_path(&self, corpus: &str, split: &str) -> std::path::PathBuf {
        self.root.join("data").join(format!("{corpus}.{split}.bin"))
    }

    pub fn artifact_path(&self, spec: &ArtifactSpec) -> std::path::PathBuf {
        self.root.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 61,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_head: 16,
            d_ffn: 64,
            rope_theta: 10000.0,
            max_seq: 32,
            n_params: 0,
        }
    }

    #[test]
    fn param_order_structure() {
        let c = cfg();
        let order = c.param_order();
        assert_eq!(order.len(), 2 + 9 * c.n_layers + 1);
        assert_eq!(order[0], "emb");
        assert_eq!(order[1], "layers.0.attn_norm");
        assert_eq!(order.last().unwrap(), "head");
    }

    #[test]
    fn shapes() {
        let c = cfg();
        assert_eq!(c.param_shape("emb").unwrap(), vec![61, 32]);
        assert_eq!(c.param_shape("layers.1.wo").unwrap(), vec![32, 32]);
        assert_eq!(c.param_shape("layers.0.wdown").unwrap(), vec![64, 32]);
        assert!(c.param_shape("layers.0.bogus").is_err());
    }

    #[test]
    fn weights_validate() {
        let c = cfg();
        let mut w = Weights::new();
        for name in c.param_order() {
            w.set(&name, Tensor::zeros(&c.param_shape(&name).unwrap()));
        }
        w.validate(&c).unwrap();
        w.set("emb", Tensor::zeros(&[2, 2]));
        assert!(w.validate(&c).is_err());
    }

    #[test]
    fn manifest_parsing() {
        let src = r#"{"models":{"t":{"config":{"vocab":61,"d_model":32,"n_layers":2,
          "n_heads":2,"d_head":16,"d_ffn":64,"rope_theta":10000.0,"max_seq":32,"n_params":123},
          "param_order":["emb"],
          "artifacts":{"fwd":{"file":"t_fwd.hlo.txt",
            "inputs":[{"name":"emb","shape":[61,32],"dtype":"float32"},
                      {"name":"tokens","shape":[8,64],"dtype":"int32"}],
            "outputs":["logits"]}}}}}"#;
        let m = Manifest { root: "/tmp".into(), json: Json::parse(src).unwrap() };
        assert_eq!(m.models(), vec!["t".to_string()]);
        let c = m.config("t").unwrap();
        assert_eq!(c.d_ffn, 64);
        let a = m.artifact("t", "fwd").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].2, "int32");
        assert_eq!(a.outputs, vec!["logits".to_string()]);
    }
}
