//! GPTQ (Frantar et al. 2022) — Hessian-aware weight quantization.
//!
//! The paper applies GPTQ *on the rotated weights* (§4.1): rotation handles
//! activation outliers, GPTQ handles weight rounding error. For a linear
//! `y = X W` with `W (k, n)`:
//!
//!   H = 2 Σ XᵀX + λI,   λ = percdamp · mean(diag H)
//!   U = chol_upper(H⁻¹)
//!   for i in 0..k:
//!       Q[i,:]  = quant(W[i,:])          (per-output-channel grids)
//!       err     = (W[i,:] − Q[i,:]) / U[i,i]
//!       W[j,:] −= U[i,j] · err           for j > i   (error feedback)
//!
//! Calibration activations come from the `fwd_stats` artifact taps; the
//! coordinator accumulates XᵀX per linear and calls [`gptq_quantize`].

use anyhow::{Context, Result};

use crate::linalg::{cholesky, spd_inverse, transpose};
use crate::quant::{self, Granularity, QuantSpec};
use crate::tensor::Tensor;

/// Running XᵀX accumulator for one linear layer's input.
#[derive(Clone, Debug)]
pub struct HessianAccum {
    pub h: Tensor,
    pub n_rows: usize,
}

impl HessianAccum {
    pub fn new(k: usize) -> Self {
        Self { h: Tensor::zeros(&[k, k]), n_rows: 0 }
    }

    /// Add a batch of input rows `x (rows, k)`.
    pub fn add_batch(&mut self, x: &Tensor) {
        let k = self.h.shape[0];
        assert_eq!(x.last_dim(), k, "activation dim mismatch");
        let rows = x.rows_2d();
        // H += X^T X (upper triangle enough, but keep it simple and full).
        for r in 0..rows {
            let row = &x.data[r * k..(r + 1) * k];
            for i in 0..k {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                let hrow = &mut self.h.data[i * k..(i + 1) * k];
                for (hv, &xj) in hrow.iter_mut().zip(row) {
                    *hv += xi * xj;
                }
            }
        }
        self.n_rows += rows;
    }
}

/// Per-output-channel symmetric scales from the *original* weights.
fn column_scales(w: &Tensor, bits: f32) -> Vec<f32> {
    let (k, n) = (w.shape[0], w.shape[1]);
    let n_sym = (bits - 1.0).exp2() - 1.0;
    let mut scales = vec![0.0f32; n];
    for c in 0..n {
        let mut absmax = 0.0f32;
        for r in 0..k {
            absmax = absmax.max(w.data[r * n + c].abs());
        }
        scales[c] = (absmax / n_sym).max(quant::EPS);
    }
    scales
}

/// Quantize one weight row onto the per-column grids.
fn quant_row(row: &[f32], scales: &[f32], bits: f32) -> Vec<f32> {
    let n_sym = (bits - 1.0).exp2() - 1.0;
    row.iter()
        .zip(scales)
        .map(|(&w, &s)| (w / s).round_ties_even().clamp(-n_sym - 1.0, n_sym) * s)
        .collect()
}

/// GPTQ-quantize `w (k, n)` given the accumulated Hessian (XᵀX).
pub fn gptq_quantize(w: &Tensor, hessian: &HessianAccum, bits: f32, percdamp: f32) -> Result<Tensor> {
    let (k, n) = (w.shape[0], w.shape[1]);
    assert_eq!(hessian.h.shape, vec![k, k]);

    // Damped Hessian: H = 2 XᵀX + λ I.
    let mut h = hessian.h.scale(2.0);
    let mean_diag = (0..k).map(|i| h.at2(i, i)).sum::<f32>() / k as f32;
    let lambda = (percdamp * mean_diag).max(1e-6);
    for i in 0..k {
        let v = h.at2(i, i) + lambda;
        h.set2(i, i, v);
    }

    // U = upper Cholesky factor of H⁻¹ (standard GPTQ trick: gives both the
    // 1/U[i,i] normalization and the forward error-propagation row U[i, i..]).
    let hinv = spd_inverse(&h).context("inverting damped Hessian")?;
    let l = cholesky(&hinv).context("cholesky of H^-1")?;
    let u = transpose(&l);

    let scales = column_scales(w, bits);
    let mut wk = w.clone();
    let mut q = Tensor::zeros(&[k, n]);

    for i in 0..k {
        let qrow = quant_row(wk.row(i), &scales, bits);
        let uii = u.at2(i, i).max(1e-10);
        // err = (w_i - q_i)/U[i,i]; propagate to remaining rows.
        let err: Vec<f32> = wk.row(i).iter().zip(&qrow).map(|(w, q)| (w - q) / uii).collect();
        q.row_mut(i).copy_from_slice(&qrow);
        for j in i + 1..k {
            let uij = u.at2(i, j);
            if uij == 0.0 {
                continue;
            }
            let wrow = wk.row_mut(j);
            for (wv, &e) in wrow.iter_mut().zip(&err) {
                *wv -= uij * e;
            }
        }
    }
    Ok(q)
}

/// RTN on the same grid — the baseline GPTQ is compared against.
pub fn rtn_quantize(w: &Tensor, bits: f32) -> Tensor {
    quant::fake_quant(
        w,
        &QuantSpec { bits, symmetric: true, clip_ratio: 1.0, granularity: Granularity::PerColumn },
    )
}

/// Proxy loss ‖X(W − Q)‖² = tr((W−Q)ᵀ H (W−Q)) / rows — what GPTQ minimizes.
pub fn hessian_weighted_error(w: &Tensor, q: &Tensor, hessian: &HessianAccum) -> f32 {
    let d = w.sub(q);
    let hd = crate::linalg::matmul(&hessian.h, &d);
    let mut tr = 0.0f32;
    let (k, n) = (d.shape[0], d.shape[1]);
    for i in 0..k {
        for j in 0..n {
            tr += d.data[i * n + j] * hd.data[i * n + j];
        }
    }
    tr / hessian.n_rows.max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::Gen;
    use crate::util::prng::Prng;

    /// Correlated activations (low-rank + noise) — the regime where GPTQ's
    /// error feedback beats RTN.
    fn correlated_acts(g: &mut Gen, rows: usize, k: usize) -> Tensor {
        let rank = (k / 4).max(1);
        let a = g.tensor(&[rows, rank], 1.0);
        let b = g.tensor(&[rank, k], 1.0);
        let base = crate::linalg::matmul(&a, &b);
        let noise = g.tensor(&[rows, k], 0.05);
        base.add(&noise)
    }

    #[test]
    fn hessian_accumulates() {
        let mut acc = HessianAccum::new(3);
        let x = Tensor::new(vec![2, 3], vec![1., 0., 2., 0., 1., 1.]);
        acc.add_batch(&x);
        assert_eq!(acc.n_rows, 2);
        // H[0][2] = 1*2 + 0*1 = 2
        assert!((acc.h.at2(0, 2) - 2.0).abs() < 1e-6);
        assert!((acc.h.at2(2, 2) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_inputs() {
        let mut g = Gen { rng: Prng::new(42) };
        let k = 32;
        let n = 16;
        let w = g.tensor(&[k, n], 0.5);
        let x = correlated_acts(&mut g, 256, k);
        let mut acc = HessianAccum::new(k);
        acc.add_batch(&x);
        let q_gptq = gptq_quantize(&w, &acc, 3.0, 0.01).unwrap();
        let q_rtn = rtn_quantize(&w, 3.0);
        let e_gptq = hessian_weighted_error(&w, &q_gptq, &acc);
        let e_rtn = hessian_weighted_error(&w, &q_rtn, &acc);
        assert!(
            e_gptq < e_rtn,
            "GPTQ ({e_gptq}) should beat RTN ({e_rtn}) on the Hessian-weighted objective"
        );
    }

    #[test]
    fn gptq_nearly_exact_at_high_bits() {
        let mut g = Gen { rng: Prng::new(7) };
        let w = g.tensor(&[16, 8], 0.3);
        let x = g.tensor(&[64, 16], 1.0);
        let mut acc = HessianAccum::new(16);
        acc.add_batch(&x);
        let q = gptq_quantize(&w, &acc, 12.0, 0.01).unwrap();
        assert!(w.sub(&q).max_abs() < 2e-3);
    }

    #[test]
    fn gptq_outputs_on_grid() {
        let mut g = Gen { rng: Prng::new(9) };
        let w = g.tensor(&[12, 6], 1.0);
        let x = g.tensor(&[40, 12], 1.0);
        let mut acc = HessianAccum::new(12);
        acc.add_batch(&x);
        let bits = 4.0;
        let q = gptq_quantize(&w, &acc, bits, 0.01).unwrap();
        let scales = column_scales(&w, bits);
        for r in 0..12 {
            for c in 0..6 {
                let v = q.at2(r, c) / scales[c];
                assert!((v - v.round()).abs() < 1e-3, "off grid at ({r},{c}): {v}");
            }
        }
    }

    #[test]
    fn degenerate_hessian_handled_by_damping() {
        // Rank-1 activations: undamped H is singular; percdamp must save it.
        let mut g = Gen { rng: Prng::new(11) };
        let w = g.tensor(&[8, 4], 0.5);
        let dir = g.tensor(&[1, 8], 1.0);
        let coef = g.tensor(&[32, 1], 1.0);
        let x = crate::linalg::matmul(&coef, &dir);
        let mut acc = HessianAccum::new(8);
        acc.add_batch(&x);
        let q = gptq_quantize(&w, &acc, 4.0, 0.01).unwrap();
        assert!(q.data.iter().all(|v| v.is_finite()));
    }
}
