//! Evaluation engines: perplexity, zero-shot task scoring, and the
//! activation-statistics / SNR analyses behind Figs. 2, 3 and 8 / Table 14.
//!
//! All model compute goes through the AOT artifacts via PJRT; this module
//! owns batching, cross-entropy, choice scoring, and the statistics.
//! Weight literals are converted once per session and reused across batches
//! (the dominant cost at these model sizes is the conversion, not the
//! matmuls — see EXPERIMENTS.md §Perf).

use anyhow::{anyhow, Result};

use crate::data::TaskSuite;
use crate::model::Weights;
use crate::runtime::{Executable, Value};
use crate::tensor::Tensor;

/// The 8-scalar runtime quantization vector — ABI mirror of
/// `python/compile/model.py::qcfg_vector`:
/// `[a_bits, kv_bits, a_sym, kv_sym, a_clip, kv_clip, w_bits, w_sym]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QcfgVec(pub [f32; 8]);

impl QcfgVec {
    pub fn fp() -> Self {
        Self([16.0, 16.0, 0.0, 0.0, 1.0, 1.0, 16.0, 1.0])
    }

    pub fn from_pipeline(cfg: &crate::config::PipelineConfig) -> Self {
        // Weight quantization happens offline (RTN/GPTQ), so w_bits stays 16
        // here; only the LLM-QAT training driver sets it.
        Self([
            cfg.bits.a,
            cfg.bits.kv,
            if cfg.a_sym { 1.0 } else { 0.0 },
            if cfg.kv_sym { 1.0 } else { 0.0 },
            cfg.a_clip,
            cfg.kv_clip,
            16.0,
            1.0,
        ])
    }

    pub fn with_a_bits(mut self, bits: f32) -> Self {
        self.0[0] = bits;
        self
    }

    pub fn with_kv_bits(mut self, bits: f32) -> Self {
        self.0[1] = bits;
        self
    }

    /// Symmetric KV grid (1.0) vs asymmetric (0.0). The quantized paged KV
    /// path stores symmetrically: R3 Gaussianizes the cached K, so the
    /// zero-point buys nothing and the per-group metadata halves.
    pub fn with_kv_sym(mut self, sym: f32) -> Self {
        self.0[3] = sym;
        self
    }

    pub fn with_w_bits(mut self, bits: f32) -> Self {
        self.0[6] = bits;
        self
    }

    pub fn tensor(&self) -> Tensor {
        Tensor::from_vec(self.0.to_vec())
    }
}

/// A reusable forward-pass session over one artifact: weight literals are
/// prepared once; per call only the token (and qcfg) literals are rebuilt.
pub struct EvalSession<'e> {
    exe: &'e Executable,
    literals: Vec<xla::Literal>,
    tokens_idx: usize,
    pub batch: usize,
    pub seq: usize,
}

impl<'e> EvalSession<'e> {
    pub fn new(exe: &'e Executable, weights: &Weights, qcfg: Option<QcfgVec>) -> Result<Self> {
        let mut values = Vec::with_capacity(exe.spec.inputs.len());
        let mut tokens_idx = None;
        let mut batch = 0;
        let mut seq = 0;
        for (i, (name, shape, dtype)) in exe.spec.inputs.iter().enumerate() {
            match name.as_str() {
                "tokens" => {
                    tokens_idx = Some(i);
                    batch = shape[0];
                    seq = shape[1];
                    values.push(Value::I32(vec![0; shape.iter().product()], shape.clone()));
                }
                "qcfg" => {
                    let q = qcfg.ok_or_else(|| anyhow!("{}: artifact needs qcfg", exe.label))?;
                    values.push(Value::F32(q.tensor()));
                }
                _ => {
                    let t = weights.get(name)?;
                    debug_assert_eq!(&t.shape, shape, "{name} {dtype}");
                    values.push(Value::F32(t.clone()));
                }
            }
        }
        let literals = exe.prepare(&values)?;
        Ok(Self {
            exe,
            literals,
            tokens_idx: tokens_idx.ok_or_else(|| anyhow!("artifact has no tokens input"))?,
            batch,
            seq,
        })
    }

    /// Run one batch of token windows; returns all artifact outputs.
    pub fn run(&mut self, windows: &[Vec<i32>]) -> Result<Vec<Tensor>> {
        let v = Value::tokens(windows, self.batch, self.seq);
        self.literals[self.tokens_idx] = match v {
            Value::I32(flat, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&flat).reshape(&dims)?
            }
            _ => unreachable!(),
        };
        self.exe.run_literals(&self.literals)
    }

    /// Run and return just the logits (output 0), shape (B, S, V).
    pub fn logits(&mut self, windows: &[Vec<i32>]) -> Result<Tensor> {
        Ok(self.run(windows)?.remove(0))
    }
}

/// Stable log-softmax NLL of next-token prediction over one window.
/// logits: (S, V) row-major slice; tokens: the window (len S).
/// Returns (sum nll, count) over positions 0..S-1 predicting 1..S.
pub fn window_nll(logits: &[f32], tokens: &[i32], vocab: usize) -> (f64, usize) {
    let s = tokens.len();
    let mut sum = 0.0f64;
    for pos in 0..s - 1 {
        let row = &logits[pos * vocab..(pos + 1) * vocab];
        let target = tokens[pos + 1] as usize;
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse: f32 = row.iter().map(|&l| (l - m).exp()).sum::<f32>().ln() + m;
        sum += (lse - row[target]) as f64;
    }
    (sum, s - 1)
}

/// Perplexity over a set of equal-length windows (the paper's Wiki column).
pub fn perplexity(session: &mut EvalSession, windows: &[Vec<i32>]) -> Result<f64> {
    let b = session.batch;
    let s = session.seq;
    let vocab = 256;
    let mut total_nll = 0.0f64;
    let mut total_cnt = 0usize;
    for chunk in windows.chunks(b) {
        let logits = session.logits(chunk)?;
        debug_assert_eq!(logits.shape, vec![b, s, vocab]);
        for (row, window) in chunk.iter().enumerate() {
            let l = &logits.data[row * s * vocab..(row + 1) * s * vocab];
            let (nll, cnt) = window_nll(l, window, vocab);
            total_nll += nll;
            total_cnt += cnt;
        }
    }
    Ok((total_nll / total_cnt.max(1) as f64).exp())
}

// ---------------------------------------------------------------------------
// Zero-shot multiple-choice scoring (lm-eval-harness style)
// ---------------------------------------------------------------------------

/// Pack one (context, choice) pair into a fixed-length window (0-padded).
fn pack_item(context: &[i32], choice: &[i32], seq: usize) -> Vec<i32> {
    let mut v = Vec::with_capacity(seq);
    v.extend_from_slice(context);
    v.extend_from_slice(choice);
    v.truncate(seq);
    while v.len() < seq {
        v.push(0);
    }
    v
}

/// Mean logprob of the choice tokens given the context (length-normalized).
fn choice_score(logits: &[f32], window: &[i32], ctx_len: usize, choice_len: usize, vocab: usize) -> f64 {
    let mut sum = 0.0f64;
    let mut cnt = 0usize;
    for pos in ctx_len.saturating_sub(1)..(ctx_len + choice_len - 1).min(window.len() - 1) {
        let row = &logits[pos * vocab..(pos + 1) * vocab];
        let target = window[pos + 1] as usize;
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse: f32 = row.iter().map(|&l| (l - m).exp()).sum::<f32>().ln() + m;
        sum += (row[target] - lse) as f64;
        cnt += 1;
    }
    sum / cnt.max(1) as f64
}

/// Evaluate one suite: fraction of items whose true continuation wins.
pub fn suite_accuracy(session: &mut EvalSession, suite: &TaskSuite) -> Result<f64> {
    let seq = session.seq;
    let b = session.batch;
    let vocab = 256;
    // Flatten all (item, choice) rows, then batch them through the artifact.
    let mut rows: Vec<Vec<i32>> = Vec::new();
    let mut meta: Vec<(usize, usize, usize)> = Vec::new(); // (item, ctx_len, choice_len)
    for (ii, item) in suite.items.iter().enumerate() {
        for choice in &item.choices {
            rows.push(pack_item(&item.context, choice, seq));
            meta.push((ii, item.context.len(), choice.len()));
        }
    }
    let mut scores = vec![Vec::new(); suite.items.len()];
    let mut cursor = 0usize;
    for chunk in rows.chunks(b) {
        let logits = session.logits(chunk)?;
        for (row_in_batch, window) in chunk.iter().enumerate() {
            let (item, ctx_len, choice_len) = meta[cursor];
            let l = &logits.data[row_in_batch * seq * vocab..(row_in_batch + 1) * seq * vocab];
            scores[item].push(choice_score(l, window, ctx_len, choice_len, vocab));
            cursor += 1;
        }
    }
    let mut correct = 0usize;
    for (item, sc) in suite.items.iter().zip(&scores) {
        let best = sc
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        if best == item.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / suite.items.len().max(1) as f64)
}

/// Evaluate all suites; returns per-suite accuracy + the paper's 0-shot^8 avg.
pub fn zero_shot(session: &mut EvalSession, suites: &[TaskSuite]) -> Result<(Vec<(String, f64)>, f64)> {
    let mut per = Vec::new();
    for suite in suites {
        let acc = suite_accuracy(session, suite)?;
        per.push((suite.name.clone(), acc));
    }
    let avg = per.iter().map(|(_, a)| a).sum::<f64>() / per.len().max(1) as f64;
    Ok((per, avg))
}

// ---------------------------------------------------------------------------
// Activation statistics / SNR (Figs. 2, 3, 8; Table 14)
// ---------------------------------------------------------------------------

/// Per-layer activation statistics from one `fwd_stats` run.
#[derive(Clone, Debug)]
pub struct LayerStats {
    pub site: String,
    pub layer: usize,
    pub kurtosis: f32,
    /// 4-bit per-token quantization MSE (Fig. 3b).
    pub quant_mse_4bit: f32,
    /// 4-bit SQNR in dB.
    pub sqnr_db_4bit: f32,
    /// Per-channel absmax (for the Fig. 2 distribution plots).
    pub channel_absmax: Vec<f32>,
}

/// Compute stats for every layer of a stacked capture tensor (L, B, S, D).
pub fn capture_stats(site: &str, t: &Tensor) -> Vec<LayerStats> {
    let l = t.shape[0];
    let spec = crate::quant::QuantSpec::activation(4.0);
    (0..l)
        .map(|layer| {
            let x = t.index0(layer);
            let d = x.last_dim();
            let mut absmax = vec![0.0f32; d];
            for r in 0..x.rows_2d() {
                for (a, &v) in absmax.iter_mut().zip(x.row(r)) {
                    *a = a.max(v.abs());
                }
            }
            LayerStats {
                site: site.to_string(),
                layer,
                kurtosis: x.kurtosis(),
                quant_mse_4bit: crate::quant::quant_error_mse(&x, &spec),
                sqnr_db_4bit: crate::quant::sqnr_db(&x, &spec),
                channel_absmax: absmax,
            }
        })
        .collect()
}

/// End-to-end signal-to-quantization-noise ratio between FP logits and
/// quantized logits (paper Table 14 / Fig. 8a).
pub fn e2e_snr_db(fp_logits: &Tensor, q_logits: &Tensor) -> f32 {
    Tensor::snr_db(fp_logits, q_logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qcfg_abi() {
        let q = QcfgVec::fp();
        assert_eq!(q.0[0], 16.0);
        let q = q.with_a_bits(4.0).with_kv_bits(8.0).with_w_bits(3.0);
        assert_eq!(q.0, [4.0, 8.0, 0.0, 0.0, 1.0, 1.0, 3.0, 1.0]);
        let q = q.with_kv_sym(1.0);
        assert_eq!(q.0, [4.0, 8.0, 0.0, 1.0, 1.0, 1.0, 3.0, 1.0]);
        assert_eq!(q.tensor().shape, vec![8]);
    }

    #[test]
    fn window_nll_uniform_logits() {
        // Uniform logits -> nll = ln(V) per position.
        let vocab = 7;
        let s = 5;
        let logits = vec![0.0f32; s * vocab];
        let tokens: Vec<i32> = (0..s as i32).collect();
        let (nll, cnt) = window_nll(&logits, &tokens, vocab);
        assert_eq!(cnt, s - 1);
        let per = nll / cnt as f64;
        assert!((per - (vocab as f64).ln()).abs() < 1e-5);
    }

    #[test]
    fn window_nll_confident_correct() {
        let vocab = 4;
        let tokens = vec![0, 2, 1];
        let mut logits = vec![0.0f32; 3 * vocab];
        logits[2] = 20.0; // position 0 predicts token 2 ✓
        logits[vocab + 1] = 20.0; // position 1 predicts token 1 ✓
        let (nll, cnt) = window_nll(&logits, &tokens, vocab);
        assert_eq!(cnt, 2);
        assert!(nll < 1e-3, "nll={nll}");
    }

    #[test]
    fn pack_item_layout() {
        let w = pack_item(&[1, 2, 3], &[4, 5], 8);
        assert_eq!(w, vec![1, 2, 3, 4, 5, 0, 0, 0]);
        let w = pack_item(&[1, 2, 3], &[4, 5, 6, 7, 8, 9], 6);
        assert_eq!(w, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn choice_score_prefers_predicted() {
        // Model that deterministically predicts token 1 everywhere.
        let vocab = 4;
        let seq = 6;
        let mut logits = vec![0.0f32; seq * vocab];
        for p in 0..seq {
            logits[p * vocab + 1] = 10.0;
        }
        let ctx = [3, 3];
        let good = pack_item(&ctx, &[1, 1], seq);
        let bad = pack_item(&ctx, &[2, 2], seq);
        let sg = choice_score(&logits, &good, 2, 2, vocab);
        let sb = choice_score(&logits, &bad, 2, 2, vocab);
        assert!(sg > sb);
    }

    #[test]
    fn capture_stats_detect_outliers() {
        let mut p = crate::util::prng::Prng::new(1);
        let (l, rows, d) = (2, 64, 32);
        let mut data: Vec<f32> = (0..l * rows * d).map(|_| p.normal()).collect();
        // plant outliers in layer 1 channel 5
        for r in 0..rows {
            data[l / 2 * 0 + (1 * rows + r) * d + 5] *= 30.0;
        }
        let t = Tensor::new(vec![l, rows, d], data);
        let stats = capture_stats("resid", &t);
        assert_eq!(stats.len(), 2);
        assert!(stats[1].kurtosis > stats[0].kurtosis * 2.0);
        assert!(stats[1].quant_mse_4bit > stats[0].quant_mse_4bit);
        let mx = stats[1]
            .channel_absmax
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(mx, 5);
    }

    #[test]
    fn e2e_snr_sanity() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0]);
        let b = a.map(|x| x + 0.01);
        let c = a.map(|x| x + 1.0);
        assert!(e2e_snr_db(&a, &b) > e2e_snr_db(&a, &c));
    }
}
