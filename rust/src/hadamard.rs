//! Fast Walsh-Hadamard transform — the L3 twin of the Pallas kernel
//! (`python/compile/kernels/hadamard.py`) and the workhorse behind:
//!
//! * building dense (randomized) Hadamard rotation matrices for R1/R2
//!   (`random_hadamard`), footnote 2 of the paper;
//! * the offline H-merge of `w_down` for `SpinQuant_had` (`fwht_rows`);
//! * baseline cost accounting for the online-Hadamard overhead (Table 6).
//!
//! Uses the normalized *Sylvester* construction: H is symmetric, involutive
//! and orthonormal, so H^-1 = H^T = H.

use crate::tensor::Tensor;
use crate::util::prng::Prng;

/// In-place unnormalized butterfly pass over one row of length n = 2^k.
#[inline]
pub fn fwht_row_unnormalized(x: &mut [f32]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        let stride = h * 2;
        let mut base = 0;
        while base < n {
            for i in base..base + h {
                let a = x[i];
                let b = x[i + h];
                x[i] = a + b;
                x[i + h] = a - b;
            }
            base += stride;
        }
        h = stride;
    }
}

/// Normalized FWHT of one row (multiplies by H_n / sqrt(n)).
pub fn fwht_row(x: &mut [f32]) {
    fwht_row_unnormalized(x);
    let inv = 1.0 / (x.len() as f32).sqrt();
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// Normalized FWHT along the last axis of a tensor (any rank).
pub fn fwht_last_axis(t: &Tensor) -> Tensor {
    let n = t.last_dim();
    assert!(n.is_power_of_two(), "FWHT size {n} must be a power of two");
    let mut out = t.clone();
    let rows = out.rows_2d();
    for r in 0..rows {
        fwht_row(&mut out.data[r * n..(r + 1) * n]);
    }
    out
}

/// Apply H to the *rows* (first axis) of a 2D tensor: out = H @ W.
/// Used for the w_down H-merge (`SpinQuant_had`): H symmetric => H @ W is
/// the FWHT of W^T's rows, transposed back.
pub fn fwht_rows(w: &Tensor) -> Tensor {
    assert_eq!(w.ndim(), 2);
    let t = crate::linalg::transpose(w);
    let t = fwht_last_axis(&t);
    crate::linalg::transpose(&t)
}

/// Dense normalized Sylvester Hadamard matrix H_n / sqrt(n).
pub fn hadamard_matrix(n: usize) -> Tensor {
    assert!(n.is_power_of_two());
    let mut h = Tensor::eye(n);
    for i in 0..n {
        fwht_row(h.row_mut(i));
    }
    // H applied to identity rows yields H itself (symmetric).
    h
}

/// Randomized Hadamard rotation: H · diag(s), s ∈ {±1}^n (paper footnote 2:
/// 2^n distinct random Hadamard matrices from one H).
pub fn random_hadamard(n: usize, seed: u64) -> Tensor {
    let mut p = Prng::new(seed ^ 0x48414441);
    let signs: Vec<f32> = (0..n).map(|_| p.sign()).collect();
    let mut h = hadamard_matrix(n);
    for i in 0..n {
        let row = h.row_mut(i);
        for (v, s) in row.iter_mut().zip(&signs) {
            *v *= s;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, orthonormality_error};
    use crate::testing::prop::{forall, Gen};

    #[test]
    fn matches_dense_matrix() {
        let n = 16;
        let h = hadamard_matrix(n);
        let mut p = Prng::new(5);
        let x = Tensor::new(vec![3, n], (0..3 * n).map(|_| p.normal()).collect());
        let via_fwht = fwht_last_axis(&x);
        let via_mat = matmul(&x, &h);
        for (a, b) in via_fwht.data.iter().zip(&via_mat.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn hadamard_is_orthonormal_and_symmetric() {
        for logn in 1..=9 {
            let n = 1 << logn;
            let h = hadamard_matrix(n);
            assert!(orthonormality_error(&h) < 1e-4, "n={n}");
            let ht = crate::linalg::transpose(&h);
            for (a, b) in h.data.iter().zip(&ht.data) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn randomized_hadamard_is_orthonormal() {
        for seed in 0..5 {
            let h = random_hadamard(64, seed);
            assert!(orthonormality_error(&h) < 1e-4);
        }
    }

    #[test]
    fn distinct_seeds_distinct_matrices() {
        let a = random_hadamard(32, 1);
        let b = random_hadamard(32, 2);
        assert!(a.sub(&b).max_abs() > 1e-3);
    }

    #[test]
    fn prop_involution_and_isometry() {
        forall(97, 40, |g: &mut Gen| {
            let logn = g.int(1, 8);
            let n = 1usize << logn;
            let rows = g.int(1, 6);
            let x = g.tensor(&[rows, n], 4.0);
            let y = fwht_last_axis(&x);
            let back = fwht_last_axis(&y);
            for (a, b) in x.data.iter().zip(&back.data) {
                if (a - b).abs() > 1e-3 {
                    return Err(format!("involution broke: {a} vs {b} (n={n})"));
                }
            }
            let nx = x.frob_norm();
            let ny = y.frob_norm();
            if (nx - ny).abs() > 1e-2 * nx.max(1.0) {
                return Err(format!("not an isometry: {nx} vs {ny}"));
            }
            Ok(())
        });
    }

    #[test]
    fn fwht_rows_is_left_multiply() {
        let n = 8;
        let h = hadamard_matrix(n);
        let mut p = Prng::new(9);
        let w = Tensor::new(vec![n, 5], (0..n * 5).map(|_| p.normal()).collect());
        let got = fwht_rows(&w);
        let want = matmul(&h, &w);
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn gaussianizes_planted_outliers() {
        // The paper's core claim in miniature (Fig. 3a).
        let mut p = Prng::new(13);
        let (rows, n) = (256, 128);
        let mut x = Tensor::new(vec![rows, n], (0..rows * n).map(|_| p.normal()).collect());
        for r in 0..rows {
            x.data[r * n + 17] *= 25.0;
            x.data[r * n + 90] *= 12.0;
        }
        let before = x.kurtosis();
        let after = fwht_last_axis(&x).kurtosis();
        assert!(before > 20.0, "before={before}");
        assert!(after < 5.0, "after={after}");
    }
}
